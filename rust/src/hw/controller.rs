//! Controller unit (§3, Fig. 2): the FSM that sequences DMA-in, the
//! per-(kernel-group × channel) compute sweeps, and DMA-out, after
//! receiving the layer dimensions from the PS.
//!
//! The FSM enforces *legal* sequencing — the IP core refuses to compute
//! before its BRAMs are loaded, exactly like the real core's `start`
//! interlock — and records a phase log the benches and EXPERIMENTS.md
//! use to break a layer's cycles down.

/// Controller phases, in legal order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Idle,
    /// PS programs layer dimensions (the "information needed" of §3).
    Configure,
    /// DMA: image + weights + bias preload into BRAMs.
    DmaIn,
    /// Compute sweeps (kernel groups × channels), pipelined.
    Compute,
    /// DMA: feature map back to the PS.
    DmaOut,
    Done,
}

/// FSM with a cycle-stamped phase log.
#[derive(Clone, Debug)]
pub struct Controller {
    state: Phase,
    cycle: u64,
    log: Vec<(Phase, u64)>, // (phase, cycles spent in it)
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IllegalTransition {
    pub from: Phase,
    pub to: Phase,
}

impl std::fmt::Display for IllegalTransition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "illegal controller transition {:?} -> {:?}", self.from, self.to)
    }
}

impl std::error::Error for IllegalTransition {}

impl Default for Controller {
    fn default() -> Self {
        Self::new()
    }
}

impl Controller {
    pub fn new() -> Self {
        Controller {
            state: Phase::Idle,
            cycle: 0,
            log: Vec::new(),
        }
    }

    pub fn state(&self) -> Phase {
        self.state
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    pub fn log(&self) -> &[(Phase, u64)] {
        &self.log
    }

    fn legal(from: Phase, to: Phase) -> bool {
        use Phase::*;
        matches!(
            (from, to),
            (Idle, Configure)
                | (Configure, DmaIn)
                | (DmaIn, Compute)
                | (Compute, Compute) // repeated sweeps
                | (Compute, DmaOut)
                | (DmaOut, Done)
                | (Done, Configure) // next layer reuses the core
                | (DmaOut, Configure) // chained layers: §4.1 output BMGs feed next layer
        )
    }

    /// Advance to `to`, charging `cycles` to it.
    pub fn advance(&mut self, to: Phase, cycles: u64) -> Result<(), IllegalTransition> {
        if !Self::legal(self.state, to) {
            return Err(IllegalTransition {
                from: self.state,
                to,
            });
        }
        self.cycle += cycles;
        // Merge consecutive same-phase entries (Compute sweeps).
        if let Some(last) = self.log.last_mut() {
            if last.0 == to {
                last.1 += cycles;
                self.state = to;
                return Ok(());
            }
        }
        self.log.push((to, cycles));
        self.state = to;
        Ok(())
    }

    /// Total cycles charged to one phase.
    pub fn phase_cycles(&self, p: Phase) -> u64 {
        self.log
            .iter()
            .filter(|(ph, _)| *ph == p)
            .map(|(_, c)| c)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn happy_path() {
        let mut c = Controller::new();
        c.advance(Phase::Configure, 2).unwrap();
        c.advance(Phase::DmaIn, 100).unwrap();
        c.advance(Phase::Compute, 800).unwrap();
        c.advance(Phase::Compute, 800).unwrap();
        c.advance(Phase::DmaOut, 50).unwrap();
        c.advance(Phase::Done, 0).unwrap();
        assert_eq!(c.cycle(), 1752);
        assert_eq!(c.phase_cycles(Phase::Compute), 1600);
        // Merged compute entries: log has 5 entries, not 6.
        assert_eq!(c.log().len(), 5);
    }

    #[test]
    fn refuses_compute_before_dma() {
        let mut c = Controller::new();
        c.advance(Phase::Configure, 1).unwrap();
        let err = c.advance(Phase::Compute, 8).unwrap_err();
        assert_eq!(err.from, Phase::Configure);
        assert_eq!(err.to, Phase::Compute);
    }

    #[test]
    fn refuses_idle_to_compute() {
        let mut c = Controller::new();
        assert!(c.advance(Phase::Compute, 8).is_err());
        assert_eq!(c.state(), Phase::Idle);
    }

    #[test]
    fn layer_chaining_skips_dma_in_readback() {
        // §4.1: output BMGs can be the next layer's input — DmaOut -> Configure.
        let mut c = Controller::new();
        c.advance(Phase::Configure, 1).unwrap();
        c.advance(Phase::DmaIn, 10).unwrap();
        c.advance(Phase::Compute, 8).unwrap();
        c.advance(Phase::DmaOut, 5).unwrap();
        assert!(c.advance(Phase::Configure, 1).is_ok());
    }
}
