//! Live metrics scrape endpoint: a tiny read-only TCP server that
//! answers any HTTP GET with a Prometheus text-exposition snapshot of
//! the serving metrics — counters, stage-keyed latency histogram
//! buckets and per-worker gauges — scrapeable mid-run.
//!
//! The endpoint binds immediately ([`ScrapeServer::bind`], so port 0
//! resolves before the run starts and the address can be printed) and
//! the metric sources attach later ([`ScrapeServer::attach`]), once the
//! serving pool exists; scrapes before attach answer an empty (but
//! valid) exposition. The server never writes anything to the serving
//! state — it is read-only by construction.
//!
//! **Scrape-format stability:** the `repro_*` metric names and the
//! `stage`/`worker` label keys rendered here are a stable interface —
//! dashboards may depend on them. New series may be added; existing
//! names and label keys only change with a wire-protocol-style
//! deprecation note in the module doc.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::metrics::{LatencyHistogram, Metrics};

/// Anything that can render itself as a Prometheus text exposition.
/// The serving pool implements this (`CorePool::scrape_source`).
pub trait ScrapeSource: Send + Sync {
    fn render_prometheus(&self) -> String;
}

/// The read-only metrics endpoint. Bind early, attach late, scrape any
/// time; `stop()` joins the accept thread.
pub struct ScrapeServer {
    addr: SocketAddr,
    listener: Arc<TcpListener>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    shutdown: Arc<AtomicBool>,
    source: Arc<Mutex<Option<Arc<dyn ScrapeSource>>>>,
    scrapes: Arc<AtomicU64>,
}

impl std::fmt::Debug for ScrapeServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScrapeServer")
            .field("addr", &self.addr)
            .field("scrapes", &self.scrapes())
            .finish()
    }
}

impl ScrapeServer {
    /// Bind `addr` (port 0 for ephemeral) and start answering scrapes
    /// immediately — with an empty exposition until [`Self::attach`].
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = Arc::new(TcpListener::bind(addr)?);
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let source: Arc<Mutex<Option<Arc<dyn ScrapeSource>>>> = Arc::new(Mutex::new(None));
        let scrapes = Arc::new(AtomicU64::new(0));
        let l = Arc::clone(&listener);
        let sd = Arc::clone(&shutdown);
        let src = Arc::clone(&source);
        let hits = Arc::clone(&scrapes);
        let thread = std::thread::Builder::new()
            .name("repro-scrape".into())
            .spawn(move || loop {
                match l.accept() {
                    Ok((stream, _)) => {
                        // The stop() wake-up connection lands here.
                        if sd.load(Ordering::Relaxed) {
                            break;
                        }
                        let body = match src.lock().unwrap().clone() {
                            Some(s) => s.render_prometheus(),
                            // Bound before the run attached its pool:
                            // a valid, empty exposition (not a 404) so
                            // scrapers can poll from t=0.
                            None => "# repro: no metric sources attached yet\n".to_string(),
                        };
                        hits.fetch_add(1, Ordering::Relaxed);
                        serve_one(stream, &body);
                    }
                    // Only reachable after stop() flipped the listener
                    // non-blocking.
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if sd.load(Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => {
                        if sd.load(Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            })?;
        Ok(ScrapeServer {
            addr: local,
            listener,
            thread: Mutex::new(Some(thread)),
            shutdown,
            source,
            scrapes,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Scrapes answered so far (smoke runs assert the endpoint was
    /// actually hit mid-run).
    pub fn scrapes(&self) -> u64 {
        self.scrapes.load(Ordering::Relaxed)
    }

    /// Attach (or replace) the metric source. Called by the serving
    /// front once its pool exists; scrapes pick the new source up on
    /// their next request.
    pub fn attach(&self, source: Arc<dyn ScrapeSource>) {
        *self.source.lock().unwrap() = Some(source);
    }

    /// Stop accepting and join the accept thread (same wake pattern as
    /// the wire `TcpServer`: flip non-blocking, nudge with a throwaway
    /// connection). Idempotent.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.listener.set_nonblocking(true).ok();
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

/// Answer one HTTP connection: drain the request head (the snapshot is
/// served whatever the path — enough HTTP for Prometheus and curl),
/// write one `200` with the body, close.
fn serve_one(mut stream: TcpStream, body: &str) {
    stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(2))).ok();
    let clone = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(clone);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) if line.trim().is_empty() => break,
            Ok(_) => continue,
            Err(_) => return,
        }
    }
    let _ = write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

/// Append the counter block for one [`Metrics`] in exposition form.
pub fn render_counters(out: &mut String, m: &Metrics) {
    use std::fmt::Write as _;
    let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
    let _ = writeln!(out, "# TYPE repro_requests_total counter");
    let _ = writeln!(out, "repro_requests_total {}", c(&m.requests));
    let _ = writeln!(out, "repro_completed_total {}", c(&m.completed));
    let _ = writeln!(out, "repro_failed_total {}", c(&m.failed));
    let _ = writeln!(out, "repro_retried_total {}", c(&m.retried));
    let _ = writeln!(out, "repro_shed_total {}", c(&m.shed));
    let _ = writeln!(out, "repro_psums_total {}", c(&m.psums));
    let _ = writeln!(out, "repro_sim_cycles_total {}", c(&m.sim_cycles));
    let _ = writeln!(out, "repro_weight_hits_total {}", c(&m.weight_hits));
    let _ = writeln!(out, "repro_weight_misses_total {}", c(&m.weight_misses));
    let _ = writeln!(
        out,
        "repro_weight_bytes_saved_total {}",
        c(&m.weight_bytes_saved)
    );
    let _ = writeln!(
        out,
        "repro_wire_weight_bytes_total {}",
        c(&m.wire_weight_bytes)
    );
}

/// Append one stage histogram as a Prometheus histogram series
/// (`repro_stage_latency_us_bucket{stage=...,le=...}` cumulative
/// buckets plus `_sum` and `_count`). The top log2 bucket is
/// open-ended, so it renders as the `+Inf` bucket.
pub fn render_stage_histogram(out: &mut String, stage: &str, h: &LatencyHistogram) {
    use std::fmt::Write as _;
    let counts = h.bucket_counts();
    let mut cum = 0u64;
    for (i, n) in counts.iter().enumerate() {
        cum += n;
        let le = if i + 1 == counts.len() {
            "+Inf".to_string()
        } else {
            (1u64 << (i + 1)).to_string()
        };
        let _ = writeln!(
            out,
            "repro_stage_latency_us_bucket{{stage=\"{stage}\",le=\"{le}\"}} {cum}"
        );
    }
    let _ = writeln!(
        out,
        "repro_stage_latency_us_sum{{stage=\"{stage}\"}} {}",
        h.sum_us()
    );
    let _ = writeln!(
        out,
        "repro_stage_latency_us_count{{stage=\"{stage}\"}} {}",
        h.count()
    );
}

/// Append the gauge block for one worker: instantaneous queued load,
/// health, and the client-side weight-residency belief for its peer.
pub fn render_worker_gauges(
    out: &mut String,
    name: &str,
    load: i64,
    healthy: bool,
    known_weight_blobs: usize,
) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "repro_worker_load{{worker=\"{name}\"}} {load}");
    let _ = writeln!(
        out,
        "repro_worker_healthy{{worker=\"{name}\"}} {}",
        u8::from(healthy)
    );
    let _ = writeln!(
        out,
        "repro_worker_known_weight_blobs{{worker=\"{name}\"}} {known_weight_blobs}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fixed(&'static str);
    impl ScrapeSource for Fixed {
        fn render_prometheus(&self) -> String {
            self.0.to_string()
        }
    }

    fn http_get(addr: SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        use std::io::Read as _;
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_attached_source_and_counts_scrapes() {
        let server = ScrapeServer::bind("127.0.0.1:0").unwrap();
        // Pre-attach: valid empty exposition, not an error.
        let early = http_get(server.addr());
        assert!(early.starts_with("HTTP/1.1 200 OK"), "{early}");
        assert!(early.contains("no metric sources attached"));
        server.attach(Arc::new(Fixed("repro_requests_total 7\n")));
        let body = http_get(server.addr());
        assert!(body.contains("repro_requests_total 7"), "{body}");
        assert!(body.contains("text/plain"));
        assert_eq!(server.scrapes(), 2);
        server.stop();
    }

    #[test]
    fn stage_histogram_renders_cumulative_buckets() {
        let h = LatencyHistogram::new();
        h.record_us(10); // bucket [8,16)
        h.record_us(10);
        h.record_us(100_000); // deep bucket
        let mut out = String::new();
        render_stage_histogram(&mut out, "queue", &h);
        assert!(
            out.contains("repro_stage_latency_us_bucket{stage=\"queue\",le=\"16\"} 2"),
            "{out}"
        );
        assert!(
            out.contains("repro_stage_latency_us_bucket{stage=\"queue\",le=\"+Inf\"} 3"),
            "{out}"
        );
        assert!(out.contains("repro_stage_latency_us_count{stage=\"queue\"} 3"));
        assert!(out.contains(&format!(
            "repro_stage_latency_us_sum{{stage=\"queue\"}} {}",
            h.sum_us()
        )));
    }

    #[test]
    fn counter_and_gauge_blocks_render() {
        let m = Metrics::new();
        m.record_completion(10, 10, Duration::from_micros(5), false);
        m.record_shed();
        let mut out = String::new();
        render_counters(&mut out, &m);
        assert!(out.contains("repro_completed_total 1"), "{out}");
        assert!(out.contains("repro_shed_total 1"));
        let mut g = String::new();
        render_worker_gauges(&mut g, "remote@1.2.3.4:5", -3, true, 9);
        assert!(g.contains("repro_worker_load{worker=\"remote@1.2.3.4:5\"} -3"));
        assert!(g.contains("repro_worker_healthy{worker=\"remote@1.2.3.4:5\"} 1"));
        assert!(g.contains("repro_worker_known_weight_blobs{worker=\"remote@1.2.3.4:5\"} 9"));
    }

    #[test]
    fn stop_is_idempotent() {
        let server = ScrapeServer::bind("127.0.0.1:0").unwrap();
        server.stop();
        server.stop();
    }
}
