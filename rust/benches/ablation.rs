//! Bench: ablations of the paper's design choices (DESIGN.md exp ABL).
//!
//! * pipeline on/off — §4.2's "effectively cutting down wasted cycles";
//! * DMA bandwidth sweep — when does the transfer start to matter;
//! * §4.1 layer chaining vs per-layer DMA round-trips;
//! * batching (weight-stationary across requests) on/off;
//! * accumulator width (wrap8 silicon vs i32 production).
//!
//! All figures are *simulated hardware cycles*, the paper's own metric.

use repro::coordinator::{CnnScheduler, CoordinatorConfig, Server};
use repro::hw::dma::DmaConfig;
use repro::hw::{AccumMode, IpCore, IpCoreConfig};
use repro::model::network::EdgeCnn;
use repro::model::trace::{generate, TraceConfig};
use repro::model::{LayerSpec, Tensor, QUICKSTART};
use repro::util::prng::Prng;

fn inputs(spec: &LayerSpec, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
    let mut rng = Prng::new(seed);
    (
        Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        ),
        Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 256)),
        vec![0i32; spec.k],
    )
}

fn main() {
    println!("=== bench: ablation ===");

    // --- pipeline on/off over a few layer shapes.
    println!("\n[pipeline] two-stage load/compute overlap (§4.2):");
    for spec in [
        QUICKSTART,
        LayerSpec::new(4, 32, 32, 8),
        LayerSpec::new(16, 13, 13, 16),
    ] {
        let (img, wts, bias) = inputs(&spec, 1);
        let on = IpCore::new(IpCoreConfig::default())
            .run_layer(&spec, &img, &wts, &bias, None)
            .unwrap();
        let off = IpCore::new(IpCoreConfig {
            pipelined: false,
            ..Default::default()
        })
        .run_layer(&spec, &img, &wts, &bias, None)
        .unwrap();
        println!(
            "  {:<24} pipelined={:>8}  serial={:>8}  speedup={:.2}x",
            spec.name(),
            on.cycles.total,
            off.cycles.total,
            off.cycles.total as f64 / on.cycles.total as f64
        );
    }

    // --- DMA bandwidth sweep (bus width in bytes/beat), counting DMA.
    println!("\n[dma] bus-width sweep on quickstart (count_dma=true):");
    let (img, wts, bias) = inputs(&QUICKSTART, 2);
    for bus in [1u64, 2, 4, 8, 16] {
        let cfg = IpCoreConfig {
            count_dma: true,
            dma: DmaConfig {
                bus_bytes: bus,
                ..Default::default()
            },
            ..Default::default()
        };
        let run = IpCore::new(cfg)
            .run_layer(&QUICKSTART, &img, &wts, &bias, None)
            .unwrap();
        println!(
            "  bus={bus:>2}B/beat  dma_in={:>6} dma_out={:>6} total={:>8} (compute {:>6})",
            run.cycles.dma_in, run.cycles.dma_out, run.cycles.total, run.cycles.compute
        );
    }

    // --- layer chaining (§4.1) vs DMA round-trip per layer.
    println!("\n[chaining] §4.1 output-BRAMs-feed-next-layer vs round-trip:");
    let net = EdgeCnn::new(42);
    let first = net.specs()[0];
    let img = EdgeCnn::sample_input(1, &first);
    let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
    let run = sched.infer(&img).unwrap();
    println!(
        "  chained={} round-trip={} saving={:.1}%",
        run.total_cycles,
        run.total_cycles_dma_roundtrip,
        100.0 * (1.0 - run.total_cycles as f64 / run.total_cycles_dma_roundtrip as f64)
    );

    // --- batching: same-shape burst vs shuffled shapes (weight reuse).
    println!("\n[batching] weight-stationary across requests:");
    for (label, s52_frac, reps) in [("same-shape burst", 0.0, 24usize), ("mixed shapes", 0.5, 24)] {
        let base = generate(&TraceConfig {
            n: if s52_frac == 0.0 { 1 } else { 24 },
            s52_fraction: s52_frac,
            seed: 3,
            ..Default::default()
        });
        let trace: Vec<_> = base.into_iter().cycle().take(reps).collect();
        let mut server = Server::new(CoordinatorConfig::default());
        let report = server.run_trace(&trace);
        println!(
            "  {label:<18} weight-DMA skipped on {:.0}% of jobs",
            report.weight_dma_skip_rate * 100.0
        );
        server.shutdown();
    }

    // --- energy model (the paper's edge-power motivation, quantified).
    println!("\n[energy] per-layer estimate (activity-based; hw::power):");
    {
        use repro::hw::device::{XC7Z020_CLG400, XZCU3EG_SBVA484};
        use repro::hw::power::{estimate_layer, model_for};
        let (img, wts, bias) = inputs(&QUICKSTART, 5);
        let run = IpCore::new(IpCoreConfig::default())
            .run_layer(&QUICKSTART, &img, &wts, &bias, None)
            .unwrap();
        for dev in [XC7Z020_CLG400, XZCU3EG_SBVA484] {
            let e = estimate_layer(&QUICKSTART, &run.cycles, &run.dma, &model_for(&dev));
            println!(
                "  {:<22} mac={:.1}nJ bram={:.1}nJ dma={:.1}nJ idle={:.1}nJ total={:.1}nJ ({:.0} psums/uJ)",
                dev.name,
                e.mac_nj,
                e.bram_nj,
                e.dma_nj,
                e.idle_nj,
                e.total_nj(),
                e.psums_per_uj(QUICKSTART.psums())
            );
        }
    }

    // --- BRAM capacity: does the paper's own S52 workload fit a Z-7020?
    println!("\n[capacity] BRAM fit for the paper's 224x224x8 workload (hw::capacity):");
    {
        use repro::hw::capacity::{fits, run_layer_tiled};
        use repro::hw::device::XC7Z020_CLG400;
        use repro::model::S52;
        for (label, mode) in [("wrap8", AccumMode::Wrap8), ("i32", AccumMode::I32)] {
            let r = fits(&S52, &XC7Z020_CLG400, mode, 0.2);
            println!(
                "  {label:<6} demand={} blocks of {} -> fits={} {}",
                r.demand.blocks,
                r.device_blocks,
                r.fits,
                r.max_strip_rows
                    .map(|n| format!("(strip at <= {n} input rows)"))
                    .unwrap_or_default()
            );
        }
        // Tiled vs whole run: identical math, halo-DMA overhead only.
        let (img, wts, bias) = inputs(&S52, 52);
        let mut core = IpCore::new(IpCoreConfig::default());
        let whole = core.run_layer(&S52, &img, &wts, &bias, None).unwrap();
        let tiled = run_layer_tiled(&mut core, &S52, &img, &wts, &bias, 58).unwrap();
        assert_eq!(tiled.output.data(), whole.output.as_i32().data());
        println!(
            "  tiled s52 @58 rows: {} strips, compute {} (= whole {}), halo {} bytes extra DMA",
            tiled.strips, tiled.cycles.compute, whole.cycles.compute, tiled.halo_bytes
        );
    }

    // --- MobileNet on the fixed-function core (§4.1's own motivation).
    println!("\n[mobilenet] depthwise-separable blocks on the core (hw::depthwise):");
    {
        use repro::model::mobilenet::{mobilenet_lite_specs, MobileNetLite};
        let net = MobileNetLite::new(42);
        let img = MobileNetLite::sample_input(1, &mobilenet_lite_specs()[0]);
        let golden = net.forward_golden(&img);
        let mut core = IpCore::new(IpCoreConfig::default());
        let (sim, cycles, util) = net.infer_sim(&mut core, &img).unwrap();
        println!(
            "  bit-exact vs golden: {}; {} cycles/inference; effective MAC utilisation {:.1}% \
             (depthwise 25% PCORE-active, pointwise 11% tap-active)",
            sim.data() == golden.data(),
            cycles,
            util * 100.0
        );
    }

    // --- software baselines on this host: naive golden vs im2col+GEMM.
    println!("\n[sw-baseline] host CPU conv implementations (quickstart shape):");
    {
        use repro::bench_util::{black_box, Bencher};
        use repro::model::golden::conv3x3_i32;
        use repro::model::im2col::conv3x3_im2col;
        let (img, wts, bias) = inputs(&QUICKSTART, 6);
        let b = Bencher::quick();
        b.run_throughput("naive golden conv (MACs/s)", QUICKSTART.macs() as f64, || {
            black_box(conv3x3_i32(&img, &wts, &bias, false))
        });
        b.run_throughput("im2col+GEMM conv (MACs/s)", QUICKSTART.macs() as f64, || {
            black_box(conv3x3_im2col(&img, &wts, &bias, false))
        });
    }

    // --- accumulator width.
    println!("\n[accumulator] wrap8 (Fig.6 silicon) vs i32 (production):");
    let (img, wts, bias) = inputs(&QUICKSTART, 4);
    for (label, mode) in [("wrap8", AccumMode::Wrap8), ("i32", AccumMode::I32)] {
        let run = IpCore::new(IpCoreConfig {
            mode,
            ..Default::default()
        })
        .run_layer(&QUICKSTART, &img, &wts, &bias, None)
        .unwrap();
        println!(
            "  {label:<6} compute={} cycles (same schedule; width changes only the output BRAM word)",
            run.cycles.compute
        );
    }
}
