//! The IP core (§3, §4): four computing cores over quartered channels,
//! the BRAM sets, the DMA engine and the controller FSM, composed into
//! `run_layer` — one invocation processes one convolutional layer,
//! exactly the unit of work the paper's core accepts.
//!
//! Cycle accounting reproduces §5.2: with the two-stage pipeline on,
//! a layer's compute time is `windows × channels/4 × kernel-groups × 8`
//! cycles (loads hidden under compute), which for the 224×224×8 ⊛
//! 8×3×3×8 workload is exactly 1,577,088 cycles — 0.01408 s at the
//! Pynq Z2's 112 MHz, i.e. 0.224 GOPS in the paper's PSUMs/s accounting.

use super::bram::{ImageBrams, OutputBrams, WeightBrams};
use super::compute_core::{ComputeCore, PsumWord, SweepCycles};
use super::controller::{Controller, Phase};
use super::dma::{Dma, DmaConfig, DmaStats};
use super::pipeline;
use super::waveform::WaveTrace;
use super::AccumMode;
use crate::model::{LayerSpec, Tensor};
use crate::paper::{CYCLES_PER_PSUM_GROUP, FREQ_Z2_HZ, N_CORES, N_PCORES};

/// IP core configuration (PS-programmable knobs + model options).
#[derive(Clone, Copy, Debug)]
pub struct IpCoreConfig {
    pub freq_hz: u64,
    pub mode: AccumMode,
    /// Two-stage load/compute pipeline (§4.2) — `false` is the ablation.
    pub pipelined: bool,
    pub dma: DmaConfig,
    /// Count DMA phases in reported layer latency (the paper's §5.2
    /// throughput counts compute only; end-to-end serving counts all).
    pub count_dma: bool,
}

impl Default for IpCoreConfig {
    fn default() -> Self {
        IpCoreConfig {
            freq_hz: FREQ_Z2_HZ,
            mode: AccumMode::I32,
            pipelined: true,
            dma: DmaConfig::default(),
            count_dma: false,
        }
    }
}

/// Layer output in the configured accumulator width.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerOutput {
    Wrap8(Tensor<u8>),
    I32(Tensor<i32>),
}

impl LayerOutput {
    pub fn as_i32(&self) -> Tensor<i32> {
        match self {
            LayerOutput::I32(t) => t.clone(),
            LayerOutput::Wrap8(t) => t.map(|v| v as i32),
        }
    }

    /// Consuming variant of [`Self::as_i32`]: the common I32 case moves
    /// the tensor out instead of cloning it — the dispatch hot path
    /// hands the feature map straight to the reply channel.
    pub fn into_i32(self) -> Tensor<i32> {
        match self {
            LayerOutput::I32(t) => t,
            LayerOutput::Wrap8(t) => t.map(|v| v as i32),
        }
    }
}

/// Everything one `run_layer` produces.
#[derive(Clone, Debug)]
pub struct LayerRun {
    pub output: LayerOutput,
    pub cycles: CycleStats,
    pub dma: DmaStats,
    /// Controller phase log (cycle breakdown for EXPERIMENTS.md).
    pub phases: Vec<(Phase, u64)>,
}

/// Cycle breakdown of one layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CycleStats {
    /// Stage-2 compute: the §5.2 number (windows × C/4 × K/4 × 8).
    pub compute: u64,
    /// Pipeline fill / stalls (pipelined) or full load time (serial).
    pub load_visible: u64,
    /// Stage-1 cycles that the pipeline hid under compute.
    pub load_hidden: u64,
    pub dma_in: u64,
    pub dma_out: u64,
    /// Latency as configured (`count_dma` decides whether DMA is in).
    pub total: u64,
}

impl CycleStats {
    pub fn seconds(&self, freq_hz: u64) -> f64 {
        self.total as f64 / freq_hz as f64
    }
}

/// Throughput in the paper's accounting: PSUMs per second / 1e9.
pub fn gops_psum(psums: u64, cycles: u64, freq_hz: u64) -> f64 {
    if cycles == 0 {
        return 0.0;
    }
    let secs = cycles as f64 / freq_hz as f64;
    psums as f64 / secs / 1e9
}

/// Throughput counting real arithmetic: 9 MACs × 2 ops per PSUM.
pub fn gops_mac(psums: u64, cycles: u64, freq_hz: u64) -> f64 {
    gops_psum(psums, cycles, freq_hz) * 18.0
}

/// The IP core.
#[derive(Clone, Debug)]
pub struct IpCore {
    pub config: IpCoreConfig,
    pub cores: Vec<ComputeCore>,
    pub dma: Dma,
    pub controller: Controller,
}

impl IpCore {
    pub fn new(config: IpCoreConfig) -> Self {
        IpCore {
            config,
            cores: (0..N_CORES).map(ComputeCore::new).collect(),
            dma: Dma::new(config.dma),
            controller: Controller::new(),
        }
    }

    /// Process one convolutional layer. `bias` is always i32; Wrap8 mode
    /// takes its low byte (the PS writes the same bytes either way).
    ///
    /// Set `trace` to record the Fig. 6 signals of computing core 0.
    pub fn run_layer(
        &mut self,
        spec: &LayerSpec,
        img: &Tensor<u8>,
        weights: &Tensor<u8>,
        bias: &[i32],
        mut trace: Option<&mut WaveTrace>,
    ) -> anyhow::Result<LayerRun> {
        anyhow::ensure!(
            spec.paper_compatible(),
            "layer {:?} violates §4.1 (K % 4 != 0 or image smaller than kernel)",
            spec
        );
        anyhow::ensure!(
            img.shape() == [spec.c, spec.h, spec.w],
            "image shape {:?} != spec {:?}",
            img.shape(),
            spec
        );
        anyhow::ensure!(
            weights.shape() == [spec.k, spec.c, 3, 3],
            "weight shape {:?} != spec {:?}",
            weights.shape(),
            spec
        );
        anyhow::ensure!(bias.len() == spec.k, "bias len {} != K {}", bias.len(), spec.k);

        self.controller = Controller::new();
        self.controller.advance(Phase::Configure, 2)?;

        // --- DMA in: image + weights (+ bias preload through the PS path).
        let in_bytes =
            (img.len() + weights.len()) as u64 + (bias.len() * std::mem::size_of::<i32>()) as u64;
        let dma_in = self.dma.transfer(in_bytes);
        self.controller.advance(Phase::DmaIn, dma_in)?;

        let mut img_brams = ImageBrams::new(spec.c, spec.h, spec.w);
        img_brams.load_image(img);
        let mut wgt_brams = WeightBrams::new(spec.k, spec.c);
        wgt_brams.load_weights(weights);

        let (oh, ow) = (spec.conv_oh(), spec.conv_ow());
        let (output, sweeps) = match self.config.mode {
            AccumMode::Wrap8 => {
                let bias8: Vec<u8> = bias.iter().map(|&b| (b & 0xFF) as u8).collect();
                let mut out = OutputBrams::<u8>::new(spec.k, oh, ow);
                out.preload_bias(&bias8);
                let sweeps = self.run_sweeps(spec, &mut img_brams, &mut wgt_brams, &mut out, &mut trace);
                (LayerOutput::Wrap8(out.readout()), sweeps)
            }
            AccumMode::I32 => {
                let mut out = OutputBrams::<i32>::new(spec.k, oh, ow);
                out.preload_bias(bias);
                let sweeps = self.run_sweeps(spec, &mut img_brams, &mut wgt_brams, &mut out, &mut trace);
                (LayerOutput::I32(out.readout()), sweeps)
            }
        };

        // ReLU is not in the paper's core; the PS (or next layer's
        // requant) applies it. LayerOutput stays raw here — the
        // coordinator layer owns activation+requant (model::quant).

        // --- cycle roll-up. The 4 computing cores run in lock-step
        // parallel; each core's sweep count is C_quarter × K-groups, and
        // the slowest core (largest channel quarter) sets the pace.
        let compute = sweeps.compute;
        let load_total = sweeps.image_load + sweeps.weight_load;
        let (load_visible, load_hidden) = if self.config.pipelined {
            // Steady-state loads (<= 8 cycles) hide under compute; only
            // the first fetch of the first window is exposed as fill.
            let fill = pipeline::pipelined_closed_form(0, 5, CYCLES_PER_PSUM_GROUP) + 5;
            (fill, load_total.saturating_sub(5))
        } else {
            (load_total, 0)
        };
        self.controller
            .advance(Phase::Compute, compute + load_visible)?;

        let out_words = spec.k * oh * ow;
        let word_bytes = match self.config.mode {
            AccumMode::Wrap8 => 1,
            AccumMode::I32 => 4,
        };
        let dma_out = self.dma.transfer((out_words * word_bytes) as u64);
        self.controller.advance(Phase::DmaOut, dma_out)?;
        self.controller.advance(Phase::Done, 0)?;

        let mut total = compute + load_visible;
        if self.config.count_dma {
            total += dma_in + dma_out;
        }
        Ok(LayerRun {
            output,
            cycles: CycleStats {
                compute,
                load_visible,
                load_hidden,
                dma_in,
                dma_out,
                total,
            },
            dma: self.dma.stats,
            phases: self.controller.log().to_vec(),
        })
    }

    /// All (kernel-group × channel) sweeps. Core `i` owns channel
    /// quarter `i`; cores run in parallel, so the aggregate cycle figure
    /// is the *maximum* per-core time, while PSUM counts sum.
    fn run_sweeps<T: PsumWord>(
        &mut self,
        spec: &LayerSpec,
        img: &mut ImageBrams,
        wgt: &mut WeightBrams,
        out: &mut OutputBrams<T>,
        trace: &mut Option<&mut WaveTrace>,
    ) -> SweepCycles {
        let groups = spec.k / N_PCORES;
        let mut per_core = vec![SweepCycles::default(); N_CORES];
        for (core_idx, core) in self.cores.iter_mut().enumerate() {
            let (start, len) = super::bram::quarter_span(spec.c, core_idx);
            for g in 0..groups {
                for ch in start..start + len {
                    let tr = if core_idx == 0 {
                        trace.as_deref_mut()
                    } else {
                        None
                    };
                    let s = core.sweep(img, wgt, out, g, ch, tr);
                    let agg = &mut per_core[core_idx];
                    agg.compute += s.compute;
                    agg.image_load += s.image_load;
                    agg.weight_load += s.weight_load;
                    agg.windows += s.windows;
                }
            }
        }
        // Slowest core paces the layer (quarters can be uneven when C%4!=0).
        per_core
            .into_iter()
            .max_by_key(|s| s.compute + s.image_load + s.weight_load)
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::golden;
    use crate::util::prng::Prng;

    fn case(c: usize, h: usize, w: usize, k: usize, seed: u64) -> (LayerSpec, Tensor<u8>, Tensor<u8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        let spec = LayerSpec::new(c, h, w, k);
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let wts = Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256));
        let bias: Vec<i32> = (0..k).map(|_| rng.range_i64(0, 100) as i32).collect();
        (spec, img, wts, bias)
    }

    #[test]
    fn i32_layer_matches_golden() {
        let (spec, img, wts, bias) = case(8, 10, 12, 8, 21);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        assert_eq!(run.output.as_i32().data(), want.data());
    }

    #[test]
    fn wrap8_layer_matches_golden() {
        let (spec, img, wts, bias) = case(4, 7, 9, 4, 22);
        let bias8: Vec<u8> = bias.iter().map(|&b| (b & 0xFF) as u8).collect();
        let mut core = IpCore::new(IpCoreConfig {
            mode: AccumMode::Wrap8,
            ..Default::default()
        });
        let run = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        let want = golden::conv3x3_wrap8(&img, &wts, &bias8);
        match run.output {
            LayerOutput::Wrap8(t) => assert_eq!(t.data(), want.data()),
            _ => panic!("expected wrap8 output"),
        }
    }

    #[test]
    fn odd_channel_count_still_correct() {
        // C=3: the paper's first-layer exception (quarters are 1,1,1,0).
        let (spec, img, wts, bias) = case(3, 8, 8, 4, 23);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        assert_eq!(run.output.as_i32().data(), want.data());
    }

    #[test]
    fn s52_cycle_count_is_papers() {
        // The headline: 224x224x8 (x) 8 kernels -> 1,577,088 compute cycles.
        let (spec, img, wts, bias) = case(8, 224, 224, 8, 24);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        assert_eq!(run.cycles.compute, 1_577_088);
        // 0.01408 s at 112 MHz.
        let secs = run.cycles.compute as f64 / FREQ_Z2_HZ as f64;
        assert!((secs - 0.01408).abs() < 1e-5, "{secs}");
        // 0.224 GOPS in the paper's PSUM accounting.
        let gops = gops_psum(spec.psums(), run.cycles.compute, FREQ_Z2_HZ);
        assert!((gops - 0.224).abs() < 0.001, "{gops}");
    }

    #[test]
    fn rejects_bad_shapes() {
        let (spec, img, wts, bias) = case(4, 6, 6, 4, 25);
        let mut core = IpCore::new(IpCoreConfig::default());
        let bad_spec = LayerSpec::new(4, 6, 6, 6); // K%4 != 0
        assert!(core.run_layer(&bad_spec, &img, &wts, &bias, None).is_err());
        let mut short_bias = bias.clone();
        short_bias.pop();
        assert!(core.run_layer(&spec, &img, &wts, &short_bias, None).is_err());
    }

    #[test]
    fn pipeline_ablation_is_slower_serial() {
        let (spec, img, wts, bias) = case(8, 16, 16, 8, 26);
        let mut on = IpCore::new(IpCoreConfig::default());
        let mut off = IpCore::new(IpCoreConfig {
            pipelined: false,
            ..Default::default()
        });
        let run_on = on.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        let run_off = off.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        assert!(run_off.cycles.total > run_on.cycles.total);
        // Same math either way.
        assert_eq!(run_on.output.as_i32().data(), run_off.output.as_i32().data());
        // Pipelined mode hides what serial mode exposes.
        assert_eq!(
            run_on.cycles.load_hidden + run_on.cycles.load_visible,
            run_off.cycles.load_visible
        );
    }

    #[test]
    fn dma_accounting_toggles_total() {
        let (spec, img, wts, bias) = case(4, 8, 8, 4, 27);
        let mut without = IpCore::new(IpCoreConfig::default());
        let mut with = IpCore::new(IpCoreConfig {
            count_dma: true,
            ..Default::default()
        });
        let a = without.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        let b = with.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        assert_eq!(
            b.cycles.total,
            a.cycles.total + b.cycles.dma_in + b.cycles.dma_out
        );
    }

    #[test]
    fn phase_log_is_ordered() {
        let (spec, img, wts, bias) = case(4, 6, 6, 4, 28);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        let phases: Vec<Phase> = run.phases.iter().map(|(p, _)| *p).collect();
        assert_eq!(
            phases,
            vec![Phase::Configure, Phase::DmaIn, Phase::Compute, Phase::DmaOut, Phase::Done]
        );
    }

    #[test]
    fn gops_accounting() {
        // 2 PSUMs per cycle at 112 MHz = 0.224 G PSUM/s.
        assert!((gops_psum(2 * 112_000_000, 112_000_000, 112_000_000) - 0.224).abs() < 1e-9);
        assert!((gops_mac(100, 100, 1_000_000_000) - 18.0).abs() < 1e-9);
    }
}
