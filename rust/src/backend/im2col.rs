//! [`ConvBackend`] over the threaded im2col + blocked-GEMM host kernel.
//!
//! The serious CPU fallback. [`super::GoldenBackend`] stays in the tree
//! as the naive anchor, but a host CPU absorbing overflow traffic
//! should run convolution the way the FPGA-CNN survey literature says
//! hosts run it: lower to a patch matrix, multiply by the flattened
//! weights ([`crate::model::im2col`]), and fan the GEMM's row panels
//! across threads. Depthwise jobs have no cross-channel reduction to
//! feed a GEMM, so they parallelise the natural way instead — one
//! scoped thread per contiguous channel chunk.
//!
//! Numerics are bit-identical to the golden reference (and therefore
//! to the simulated core) for every kind and thread count — enforced
//! by the unified parity harness in `rust/tests/backend_parity.rs`.
//! The reported cycles are the backend's own [`CostModel::Im2col`]
//! quote: modelled host-equivalent work, not simulated silicon.

use super::{BackendRun, Capability, ConvBackend, CostModel, JobKind, JobPayload};
use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::im2col::conv3x3_im2col_threaded;
use crate::model::Tensor;
use crate::paper::{KH, KW};

/// Threaded im2col+GEMM host backend.
#[derive(Clone, Copy, Debug)]
pub struct Im2colBackend {
    threads: usize,
}

impl Default for Im2colBackend {
    fn default() -> Self {
        Im2colBackend::new(4)
    }
}

impl Im2colBackend {
    /// A worker fanning its kernels across `threads` scoped threads
    /// (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        Im2colBackend {
            threads: threads.max(1),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// Depthwise 3×3 with the channel axis fanned across scoped threads.
/// Each thread owns a disjoint `(chunk, OH, OW)` slice of the output;
/// per channel the arithmetic is exactly
/// [`crate::hw::depthwise::golden_depthwise3x3`]'s loop, so the result
/// is bit-identical for any thread count.
fn depthwise3x3_threaded(
    img: &Tensor<u8>,
    w: &Tensor<u8>,
    bias: &[i32],
    relu: bool,
    threads: usize,
) -> Tensor<i32> {
    let (c, h, width) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let (oh, ow) = (h - KH + 1, width - KW + 1);
    let plane = oh * ow;
    let mut out = Tensor::<i32>::zeros(&[c, oh, ow]);
    let threads = threads.clamp(1, c);
    let chans_per = c.div_ceil(threads);
    let od = out.data_mut();
    let kernel = |base: usize, chunk: &mut [i32]| {
        for (dc, plane_out) in chunk.chunks_mut(plane).enumerate() {
            let ci = base + dc;
            for y in 0..oh {
                for x in 0..ow {
                    let mut acc = bias[ci];
                    for dy in 0..KH {
                        for dx in 0..KW {
                            acc += img.at3(ci, y + dy, x + dx) as i32
                                * w.data()[(ci * KH + dy) * KW + dx] as i32;
                        }
                    }
                    if relu && acc < 0 {
                        acc = 0;
                    }
                    plane_out[y * ow + x] = acc;
                }
            }
        }
    };
    if threads == 1 {
        kernel(0, od);
        return out;
    }
    std::thread::scope(|scope| {
        for (t, chunk) in od.chunks_mut(chans_per * plane).enumerate() {
            let kernel = &kernel;
            scope.spawn(move || kernel(t * chans_per, chunk));
        }
    });
    out
}

impl ConvBackend for Im2colBackend {
    fn name(&self) -> &'static str {
        "im2col-cpu"
    }

    fn capability(&self) -> Capability {
        Capability {
            standard3x3: true,
            depthwise: true,
            pointwise_as_3x3: true,
            accum: AccumMode::I32,
            paper_specs_only: false,
            spec_allowlist: None,
        }
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Im2col {
            threads: self.threads as u64,
        }
    }

    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
        job.validate()?;
        let cost = self.cost(job.spec, job.kind);
        let output = match job.kind {
            JobKind::Standard | JobKind::PointwiseAs3x3 => {
                // Raw accumulator output, like every standard-path
                // backend: activation + requant belong to the serving
                // layer.
                conv3x3_im2col_threaded(job.img, job.weights, job.bias, false, self.threads)
            }
            JobKind::Depthwise => {
                depthwise3x3_threaded(job.img, job.weights, job.bias, job.spec.relu, self.threads)
            }
        };
        Ok(BackendRun {
            output,
            cycles: CycleStats {
                compute: cost,
                total: cost,
                ..Default::default()
            },
            wire: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;
    use crate::hw::depthwise::golden_depthwise3x3;
    use crate::model::{golden, LayerSpec, Tensor, QUICKSTART};
    use crate::util::prng::Prng;

    fn standard_payload_parts(spec: &LayerSpec, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        (
            Tensor::from_vec(
                &[spec.c, spec.h, spec.w],
                rng.bytes_below(spec.c * spec.h * spec.w, 256),
            ),
            Tensor::from_vec(
                &[spec.k, spec.c, 3, 3],
                rng.bytes_below(spec.k * spec.c * 9, 256),
            ),
            (0..spec.k).map(|_| rng.range_i64(-50, 50) as i32).collect(),
        )
    }

    #[test]
    fn standard_job_matches_golden_backend_bit_for_bit() {
        let spec = QUICKSTART;
        let (img, wts, bias) = standard_payload_parts(&spec, 61);
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let want = GoldenBackend::new().run(&payload).unwrap();
        for threads in [1usize, 2, 4] {
            let got = Im2colBackend::new(threads).run(&payload).unwrap();
            assert_eq!(got.output.data(), want.output.data(), "threads={threads}");
        }
    }

    #[test]
    fn depthwise_job_matches_golden_and_fuses_relu() {
        let spec = LayerSpec::new(8, 10, 10, 8).with_relu();
        let mut rng = Prng::new(62);
        let img = Tensor::from_vec(&[8, 10, 10], rng.bytes_below(800, 256));
        let wts = Tensor::from_vec(&[8, 3, 3], rng.bytes_below(72, 256));
        let bias: Vec<i32> = (0..8).map(|_| rng.range_i64(-200_000, 10) as i32).collect();
        let payload = JobPayload {
            kind: JobKind::Depthwise,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let want = golden_depthwise3x3(&img, &wts, &bias, true);
        for threads in [1usize, 3, 16] {
            let got = Im2colBackend::new(threads).run(&payload).unwrap();
            assert_eq!(got.output.data(), want.data(), "threads={threads}");
        }
    }

    #[test]
    fn rejects_mismatched_shapes() {
        let img = Tensor::<u8>::zeros(&[4, 8, 8]);
        let wts = Tensor::<u8>::zeros(&[4, 4, 3, 3]);
        let bias = vec![0i32; 4];
        let wrong_spec = LayerSpec::new(8, 8, 8, 4);
        let err = Im2colBackend::new(2).run(&JobPayload {
            kind: JobKind::Standard,
            spec: &wrong_spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        });
        assert!(err.is_err());
    }

    #[test]
    fn reports_its_own_cost_model_as_cycles() {
        let spec = QUICKSTART;
        let (img, wts, bias) = standard_payload_parts(&spec, 63);
        let mut be = Im2colBackend::new(4);
        assert_eq!(be.cost_model(), CostModel::Im2col { threads: 4 });
        let run = be
            .run(&JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .unwrap();
        assert_eq!(run.cycles.total, be.cost(&spec, JobKind::Standard));
    }

    #[test]
    fn raw_standard_output_ignores_spec_relu() {
        // Parity contract: standard jobs return the raw accumulator even
        // when the spec carries a fused-relu flag (the scheduler owns
        // activation); only depthwise fuses.
        let spec = LayerSpec::new(4, 6, 6, 4).with_relu();
        let (img, wts, _) = standard_payload_parts(&spec, 64);
        let bias = vec![-1_000_000i32; 4];
        let run = Im2colBackend::new(2)
            .run(&JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .unwrap();
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        assert_eq!(run.output.data(), want.data());
        assert!(run.output.data().iter().any(|&v| v < 0), "raw accumulator must go negative here");
    }
}
