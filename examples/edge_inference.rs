//! End-to-end driver (experiment E2E): serve a batch of CNN inference
//! requests through the full stack on a real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example edge_inference -- [--images N] [--cores N]
//! ```
//!
//! What happens per image:
//! * the coordinator's scheduler runs all 5 layers of the edge CNN on a
//!   simulated IP core, chaining layers through the output BRAMs
//!   (§4.1) with inter-layer requantisation;
//! * numerics are verified bit-exactly against the golden reference;
//! * the same image also goes through the AOT-compiled XLA/Pallas path.
//!
//! The report gives classification results, per-image simulated latency
//! at 112 MHz, end-to-end throughput for 1..=N cores, and the host-side
//! wall-clock cost of the simulation itself.

use repro::coordinator::CnnScheduler;
use repro::hw::ip_core::gops_psum;
use repro::hw::IpCoreConfig;
use repro::model::network::EdgeCnn;
use repro::model::Tensor;
use repro::paper::FREQ_Z2_HZ;
use repro::runtime::XlaRuntime;
use repro::util::cli::Args;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[]).map_err(|e| anyhow::anyhow!(e))?;
    let n_images = args.get_usize("images", 32).map_err(|e| anyhow::anyhow!(e))?;
    let n_cores = args.get_usize("cores", 4).map_err(|e| anyhow::anyhow!(e))?;

    let net = EdgeCnn::new(42);
    let first = net.specs()[0];
    let total_psums: u64 = net.specs().iter().map(|s| s.psums()).sum();
    println!(
        "edge CNN: {} layers, {} PSUMs/inference, input {}x{}x{}",
        net.specs().len(),
        total_psums,
        first.c,
        first.h,
        first.w
    );

    // --- serve n_images through the scheduler (simulated hardware).
    let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
    let wall = Instant::now();
    let mut sim_cycles_total = 0u64;
    let mut classes = Vec::new();
    let mut verified = 0;
    for seed in 0..n_images as u64 {
        let img = EdgeCnn::sample_input(seed, &first);
        let run = sched.infer(&img)?;
        let golden = sched.net.forward_golden(&img);
        if run.logits == golden {
            verified += 1;
        }
        sim_cycles_total += run.total_cycles;
        classes.push(run.class);
    }
    let host = wall.elapsed();

    let per_image_cycles = sim_cycles_total / n_images as u64;
    let per_image_ms = per_image_cycles as f64 / FREQ_Z2_HZ as f64 * 1e3;
    println!("\n--- simulated hardware (1 IP core @112MHz) ---");
    println!("verified bit-exact vs golden: {verified}/{n_images}");
    println!("class histogram head: {:?}...", &classes[..classes.len().min(8)]);
    println!("per-image: {per_image_cycles} cycles = {per_image_ms:.3} ms -> {:.1} img/s", 1e3 / per_image_ms);
    println!(
        "sustained: {:.4} GOPS (psum accounting)",
        gops_psum(total_psums, per_image_cycles, FREQ_Z2_HZ)
    );
    for n in [1usize, 4, 20] {
        let img_s = 1e3 / per_image_ms * n as f64;
        println!("  {n:>2} cores -> {img_s:.1} img/s");
    }
    println!(
        "host wall: {host:?} for {n_images} inferences ({:.1} sim-inferences/s on this machine, {n_cores} cores requested)",
        n_images as f64 / host.as_secs_f64()
    );

    // --- XLA path on the same images (needs the `xla` feature and
    // built artifacts; skipped otherwise).
    match XlaRuntime::with_default_registry() {
        Ok(mut rt) => {
            let params: Vec<(Tensor<u8>, Vec<i32>)> = sched
                .net
                .params
                .layers
                .iter()
                .map(|l| (l.weights.clone(), l.bias.clone()))
                .collect();
            let wall = Instant::now();
            let mut agree = 0;
            for seed in 0..n_images as u64 {
                let img = EdgeCnn::sample_input(seed, &first);
                let logits = rt.run_edge_cnn(&img, &params)?;
                let class = repro::model::network::argmax_f32(&logits);
                if class == classes[seed as usize] {
                    agree += 1;
                }
            }
            let xla_wall = wall.elapsed();
            println!("\n--- XLA/PJRT path (fused Pallas CNN, CPU) ---");
            println!(
                "platform={} {:.1} inferences/s, class agreement with hw-sim path: {agree}/{n_images}",
                rt.platform(),
                n_images as f64 / xla_wall.as_secs_f64()
            );
            println!("(fused path skips inter-layer requantisation — see DESIGN.md §5)");
        }
        Err(e) => {
            println!("\n--- XLA/PJRT path skipped: {e} ---");
        }
    }

    Ok(())
}
