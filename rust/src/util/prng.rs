//! Deterministic PRNG: SplitMix64 seeding an xoshiro256++ core.
//!
//! Used everywhere randomness is needed — test-vector generation,
//! property tests (`rust/tests/properties.rs` reports the failing seed),
//! synthetic workloads — so every run is reproducible from a `u64` seed.

/// xoshiro256++ with SplitMix64 seeding. Not cryptographic; fast and
/// statistically solid, which is all simulation inputs need.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Prng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (i64 range, `lo < hi`).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform byte.
    #[inline]
    pub fn byte(&mut self) -> u8 {
        (self.next_u64() >> 56) as u8
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill a byte slice.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for b in buf.iter_mut() {
            *b = self.byte();
        }
    }

    /// A vector of `n` bytes in `[0, hi)`.
    pub fn bytes_below(&mut self, n: usize, hi: u16) -> Vec<u8> {
        (0..n).map(|_| self.below(hi as u64) as u8).collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut p = Prng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn range_endpoints() {
        let mut p = Prng::new(9);
        for _ in 0..200 {
            let v = p.range_i64(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut p = Prng::new(11);
        for _ in 0..200 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
