//! Integration tests for the beyond-the-paper extensions: stepped
//! microarchitecture model, BRAM capacity planning + strip tiling,
//! energy model, and coordinator backpressure — each exercised through
//! the public API against the core experiment artefacts.

use repro::coordinator::{CoordinatorConfig, Server};
use repro::hw::bram::{ImageBrams, OutputBrams, WeightBrams};
use repro::hw::capacity::{demand, fits, run_layer_tiled};
use repro::hw::device::XC7Z020_CLG400;
use repro::hw::power::{estimate_layer, model_for};
use repro::hw::stepped::sweep_stepped;
use repro::hw::waveform::{fig6_stimulus, FIG6_PSUMS};
use repro::hw::{AccumMode, IpCore, IpCoreConfig};
use repro::model::trace::{generate, TraceConfig};
use repro::model::{LayerSpec, Tensor, S52};
use repro::util::prng::Prng;

#[test]
fn stepped_microarchitecture_reproduces_fig6() {
    // The per-cycle derivation (explicit adder tree, port tracking) must
    // land on the same figure values as the fast functional model.
    let (_, img, weights, _) = fig6_stimulus();
    let mut ib = ImageBrams::new(1, 5, 5);
    ib.load_image(&img);
    let mut wb = WeightBrams::new(4, 1);
    wb.load_weights(&weights);
    let mut out = OutputBrams::<u8>::new(4, 3, 3);
    out.preload_bias(&[0; 4]);
    let run = sweep_stepped(&mut ib, &mut wb, &mut out, 0, 0);
    assert!(run.ports.violations.is_empty(), "dual-port bound holds");
    let got = out.readout();
    for (j, expected) in FIG6_PSUMS.iter().enumerate() {
        let row: Vec<u8> = (0..9).map(|i| got.at3(j, i / 3, i % 3)).collect();
        assert_eq!(&row[..], expected, "psum_{j} via the stepped model");
    }
    // 8-cycle schedule: weight staging (5) + 9 windows x 8.
    assert_eq!(run.cycles, 5 + 72);
}

#[test]
fn s52_needs_strips_on_the_papers_own_board_and_tiling_is_exact() {
    let report = fits(&S52, &XC7Z020_CLG400, AccumMode::Wrap8, 0.2);
    assert!(!report.fits, "224x224x8 exceeds Z-7020 BRAM even at 1B/word");
    let rows = fits(&S52, &XC7Z020_CLG400, AccumMode::I32, 0.2)
        .max_strip_rows
        .expect("strip plan exists");

    // Tile a scaled-down S52 (same C/K, smaller H) with the planner's
    // granularity and check bit-exactness + zero compute overhead.
    let spec = LayerSpec::new(8, 64, 64, 8);
    let mut rng = Prng::new(64);
    let img = Tensor::from_vec(
        &[spec.c, spec.h, spec.w],
        rng.bytes_below(spec.c * spec.h * spec.w, 256),
    );
    let wts = Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 256));
    let bias = vec![3i32; spec.k];
    let mut core = IpCore::new(IpCoreConfig::default());
    let whole = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
    let tiled = run_layer_tiled(&mut core, &spec, &img, &wts, &bias, rows.min(spec.h)).unwrap();
    assert_eq!(tiled.output.data(), whole.output.as_i32().data());
    assert_eq!(tiled.cycles.compute, whole.cycles.compute);
}

#[test]
fn capacity_demand_scales_with_mode_word_size() {
    let w8 = demand(&S52, AccumMode::Wrap8);
    let w32 = demand(&S52, AccumMode::I32);
    assert_eq!(w8.image_bytes, w32.image_bytes);
    assert_eq!(w8.output_bytes * 4, w32.output_bytes);
    assert!(w32.blocks > w8.blocks);
}

#[test]
fn energy_per_inference_is_reported_and_family_ordered() {
    let spec = LayerSpec::new(8, 16, 16, 8);
    let mut rng = Prng::new(8);
    let img = Tensor::from_vec(
        &[spec.c, spec.h, spec.w],
        rng.bytes_below(spec.c * spec.h * spec.w, 256),
    );
    let wts = Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 256));
    let run = IpCore::new(IpCoreConfig::default())
        .run_layer(&spec, &img, &wts, &vec![0; spec.k], None)
        .unwrap();
    let e7 = estimate_layer(&spec, &run.cycles, &run.dma, &model_for(&XC7Z020_CLG400));
    let eu = estimate_layer(
        &spec,
        &run.cycles,
        &run.dma,
        &model_for(&repro::hw::device::XZCU3EG_SBVA484),
    );
    assert!(e7.total_nj() > 0.0);
    assert!(eu.total_nj() < e7.total_nj(), "16nm beats 28nm");
}

#[test]
fn backpressure_bounds_inflight_work_without_losing_requests() {
    let trace = generate(&TraceConfig {
        n: 30,
        mean_gap_us: 0,
        s52_fraction: 0.0,
        depthwise_fraction: 0.0,
        seed: 9,
    });
    let unbounded = {
        let mut s = Server::new(CoordinatorConfig::default().with_cores(2));
        let r = s.run_trace(&trace);
        s.shutdown();
        r
    };
    let bounded = {
        let mut s = Server::new(CoordinatorConfig {
            max_inflight_psums: Some(30_000),
            ..CoordinatorConfig::default().with_cores(2)
        });
        let r = s.run_trace(&trace);
        s.shutdown();
        r
    };
    assert_eq!(unbounded.n_requests, 30);
    assert_eq!(bounded.n_requests, 30);
    assert_eq!(bounded.total_psums, unbounded.total_psums);
    // Bounding in-flight work must cut queueing latency (p99).
    assert!(
        bounded.p99_us <= unbounded.p99_us,
        "bounded p99 {} vs unbounded {}",
        bounded.p99_us,
        unbounded.p99_us
    );
}
