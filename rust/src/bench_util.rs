//! Micro-benchmark harness (no `criterion` offline).
//!
//! `cargo bench` runs each `rust/benches/*.rs` with `harness = false`;
//! those binaries use [`Bencher`] for warmup + timed iterations and
//! report median / mean / p95 wall time plus a derived throughput line.
//! Output is stable, grep-able text — EXPERIMENTS.md quotes it directly.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn per_iter_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

pub struct Bencher {
    /// Target wall time to spend measuring each benchmark.
    pub budget: Duration,
    /// Warmup time before measurement.
    pub warmup: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_secs(2),
            warmup: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            budget: Duration::from_millis(500),
            warmup: Duration::from_millis(100),
            max_iters: 2_000,
        }
    }

    /// Time `f` repeatedly; returns stats over per-iteration durations.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup, also estimates the per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let est = warm_start.elapsed() / warm_iters.max(1) as u32;

        let target_iters = if est.is_zero() {
            self.max_iters
        } else {
            ((self.budget.as_secs_f64() / est.as_secs_f64()).ceil() as usize)
                .clamp(5, self.max_iters)
        };

        let mut samples = Vec::with_capacity(target_iters);
        for _ in 0..target_iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let stats = BenchStats {
            name: name.to_string(),
            iters: samples.len(),
            median: samples[samples.len() / 2],
            mean: total / samples.len() as u32,
            p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
            min: samples[0],
        };
        stats
    }

    /// Bench and print one standard report line.
    pub fn run<T>(&self, name: &str, f: impl FnMut() -> T) -> BenchStats {
        let s = self.bench(name, f);
        println!(
            "bench {:<40} iters={:<6} median={:>12?} mean={:>12?} p95={:>12?} min={:>12?}",
            s.name, s.iters, s.median, s.mean, s.p95, s.min
        );
        s
    }

    /// Bench and print with a derived items/second throughput figure
    /// (`items` = work units per iteration, e.g. MACs or requests).
    pub fn run_throughput<T>(&self, name: &str, items: f64, f: impl FnMut() -> T) -> BenchStats {
        let s = self.bench(name, f);
        let per_sec = items / s.per_iter_secs();
        println!(
            "bench {:<40} iters={:<6} median={:>12?} throughput={:.4e} items/s",
            s.name, s.iters, s.median, per_sec
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let b = Bencher {
            budget: Duration::from_millis(50),
            warmup: Duration::from_millis(10),
            max_iters: 100,
        };
        let s = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(s.iters >= 5);
        assert!(s.min <= s.median && s.median <= s.p95);
        assert!(s.median.as_nanos() > 0);
    }
}
