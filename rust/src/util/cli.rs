//! Tiny CLI argument helpers (no `clap` offline): `--flag`, `--key value`
//! and positional arguments, with typed accessors and a usage error path.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `--key value` pairs become options unless the
    /// key is listed in `bool_flags`, in which case it is a bare flag.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&key) {
                    out.flags.push(key.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{key} expects a value"))?;
                    out.options.insert(key.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &argv(&["cmd", "--cores", "8", "--verbose", "pos2", "--rate=0.5"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["cmd", "pos2"]);
        assert_eq!(a.get_usize("cores", 1).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(&[]), &[]).unwrap();
        assert_eq!(a.get_usize("cores", 4).unwrap(), 4);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["--cores"]), &[]).is_err());
    }

    #[test]
    fn bad_type_errors() {
        let a = Args::parse(&argv(&["--cores", "abc"]), &[]).unwrap();
        assert!(a.get_usize("cores", 1).is_err());
    }
}
