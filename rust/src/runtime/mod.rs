//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from JAX + Pallas) and executes
//! them on the XLA CPU client. Python is never on this path.
//!
//! * [`artifacts`] — parses `manifest.json` (via [`crate::util::json`])
//!   into a registry keyed by the layer-spec name shared with
//!   `python/compile/model.py`. Always available.
//! * `executor` — PJRT client + compiled-executable cache; converts
//!   between [`crate::model::Tensor`] and `xla::Literal`. Compiled only
//!   with the `xla` feature; without it, [`XlaRuntime`] is an
//!   API-identical stub whose constructors return `Err`, so every
//!   caller (examples, benches, `backend::XlaBackend`, parity tests)
//!   degrades by skipping the XLA path.

pub mod artifacts;

#[cfg(feature = "xla")]
pub mod executor;

#[cfg(not(feature = "xla"))]
pub mod executor_stub;

pub use artifacts::{ArtifactRegistry, Variant};

#[cfg(feature = "xla")]
pub use executor::XlaRuntime;

#[cfg(not(feature = "xla"))]
pub use executor_stub::XlaRuntime;
