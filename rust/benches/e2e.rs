//! Bench: end-to-end serving — full CNN inference through the layer
//! scheduler, mixed-trace throughput through the coordinator's core
//! pool at 1 / 4 / 20 cores (the §5.2 scaling story, measured through
//! the real dispatch path rather than multiplied out), and the host
//! GEMM calibration leg: naive `gemm_i32` vs the blocked parallel
//! kernel behind `Im2colBackend` (the measured ratio anchors
//! `CostModel::Im2col`; see `IM2COL_MACS_PER_UNIT`).

use repro::bench_util::{black_box, Bencher};
use repro::coordinator::{CnnScheduler, CoordinatorConfig, Server};
use repro::hw::IpCoreConfig;
use repro::model::im2col::{gemm_i32, gemm_i32_blocked, im2col, weights_matrix};
use repro::model::network::EdgeCnn;
use repro::model::trace::{generate, TraceConfig};
use repro::model::{LayerSpec, Tensor};
use repro::paper::FREQ_Z2_HZ;
use repro::util::prng::Prng;

fn main() {
    println!("=== bench: e2e (edge CNN + coordinator) ===");
    let b = Bencher::default();

    // --- single inference through the scheduler.
    {
        let net = EdgeCnn::new(42);
        let first = net.specs()[0];
        let img = EdgeCnn::sample_input(1, &first);
        let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
        let run = sched.infer(&img).unwrap();
        println!(
            "sim latency/inference: {} cycles = {:.3} ms @112MHz (chaining; {} with DMA round-trips)",
            run.total_cycles,
            run.total_cycles as f64 / FREQ_Z2_HZ as f64 * 1e3,
            run.total_cycles_dma_roundtrip
        );
        b.run("edge_cnn inference (hw-sim, host time)", || {
            black_box(sched.infer(&img).unwrap())
        });
    }

    // --- coordinator trace throughput at increasing core counts.
    let trace = generate(&TraceConfig {
        n: 32,
        mean_gap_us: 0,
        s52_fraction: 0.0,
        depthwise_fraction: 0.0,
        seed: 7,
    });
    for cores in [1usize, 4, 20] {
        let mut server = Server::new(CoordinatorConfig::default().with_cores(cores));
        let report = server.run_trace(&trace);
        println!(
            "coordinator {:>2} cores: sim_gops={:.4} host_rps={:.1} p50={}us p99={}us wdma_skip={:.0}%",
            cores,
            report.sim_gops_psum,
            report.host_rps,
            report.p50_us,
            report.p99_us,
            report.weight_dma_skip_rate * 100.0
        );
        server.shutdown();
    }

    // --- heterogeneous pools: sim cores + host fallback (naive golden
    // vs threaded im2col), same mixed-kind trace.
    {
        let mixed = generate(&TraceConfig {
            n: 32,
            mean_gap_us: 0,
            s52_fraction: 0.0,
            depthwise_fraction: 0.25,
            seed: 8,
        });
        for (label, golden_n, im2col_n) in
            [("4 sim + 2 golden", 2usize, 0usize), ("4 sim + 2 im2col", 0, 2)]
        {
            let mut server = Server::new(
                CoordinatorConfig::default()
                    .with_cores(4)
                    .with_golden_workers(golden_n)
                    .with_im2col_workers(im2col_n),
            );
            let report = server.run_trace(&mixed);
            println!(
                "heterogeneous {label}: host_rps={:.1} p99={}us mix={:?}",
                report.host_rps, report.p99_us, report.backend_mix
            );
            server.shutdown();
        }
    }

    // --- host GEMM calibration: naive vs blocked-parallel on the
    // 32×32 c8→k16 layer (900×72 patches @ 72×16 weights). The printed
    // ratio is what `CostModel::Im2col` is calibrated against; the
    // blocked kernel at 4 threads must beat the naive loop.
    {
        let spec = LayerSpec::new(8, 32, 32, 16);
        let mut rng = Prng::new(99);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        );
        let wts = Tensor::from_vec(
            &[spec.k, spec.c, 3, 3],
            rng.bytes_below(spec.k * spec.c * 9, 256),
        );
        let (patches, _, _) = im2col(&img);
        let wm = weights_matrix(&wts);
        assert_eq!(
            gemm_i32_blocked(&patches, &wm, 4).data(),
            gemm_i32(&patches, &wm).data(),
            "blocked GEMM must stay bit-identical to naive"
        );
        let macs = spec.macs() as f64;
        let naive = b.run_throughput("gemm_i32 naive 900x72@72x16 (MACs/s)", macs, || {
            black_box(gemm_i32(&patches, &wm))
        });
        let blocked1 = b.run_throughput("gemm_i32_blocked t=1 (MACs/s)", macs, || {
            black_box(gemm_i32_blocked(&patches, &wm, 1))
        });
        let blocked4 = b.run_throughput("gemm_i32_blocked t=4 (MACs/s)", macs, || {
            black_box(gemm_i32_blocked(&patches, &wm, 4))
        });
        println!(
            "blocked-vs-naive speedup: t=1 {:.2}x, t=4 {:.2}x (CostModel::Im2col assumes {}x/thread)",
            naive.per_iter_secs() / blocked1.per_iter_secs(),
            naive.per_iter_secs() / blocked4.per_iter_secs(),
            repro::backend::IM2COL_MACS_PER_UNIT
        );
    }

    // --- host cost of one dispatch round trip (scheduling overhead).
    {
        let mut server = Server::new(CoordinatorConfig::default());
        let single = generate(&TraceConfig {
            n: 1,
            s52_fraction: 0.0,
            ..Default::default()
        });
        b.run("coordinator 1-request round trip", || {
            black_box(server.run_trace(&single))
        });
        server.shutdown();
    }
}
