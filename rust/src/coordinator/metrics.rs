//! Coordinator metrics: counters, simulated-cycle roll-up and
//! stage-keyed log-bucketed latency histograms (std-only, lock-free
//! counters, scrapeable mid-run via `telemetry::scrape`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 latency buckets (2^20 µs ≈ 1 s; the last bucket is
/// open-ended).
pub const N_LATENCY_BUCKETS: usize = 21;

const N_BUCKETS: usize = N_LATENCY_BUCKETS;

/// Log2-bucketed latency histogram, 1 µs .. ~1 s.
#[derive(Debug)]
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i µs, 2^(i+1) µs).
    buckets: Vec<AtomicU64>,
    /// Total recorded µs (Prometheus `_sum`; also tightens the top
    /// quantile estimate's sanity checks).
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().max(1) as u64);
    }

    /// Record a latency already expressed in µs (clamped to ≥ 1).
    pub fn record_us(&self, us: u64) {
        let us = us.max(1);
        let bucket = (63 - us.leading_zeros() as usize).min(N_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Total recorded µs across every sample.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Snapshot of the raw bucket counters (bucket i counts samples in
    /// [2^i µs, 2^(i+1) µs); the last bucket is open-ended).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }

    /// Fold `other`'s samples into `self` (bucket-wise add). The result
    /// is indistinguishable from having recorded both sample streams
    /// into one histogram.
    pub fn merge(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Estimate (µs) of quantile `q` (0..1], linearly interpolated
    /// within the winning bucket: rank r of b samples in [lo, hi) maps
    /// to `lo + (r/b)·(hi−lo)` rather than the coarse bucket upper
    /// bound (which overstated p50 by up to 2× on log2 buckets).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (((total as f64) * q).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if seen + n >= target {
                let lo = 1u64 << i;
                let hi = 1u64 << (i + 1);
                let rank = (target - seen) as f64; // 1-based within bucket
                let frac = rank / n as f64;
                return lo + (frac * (hi - lo) as f64).round() as u64;
            }
            seen += n;
        }
        1u64 << N_BUCKETS
    }
}

/// Number of per-layer stream histograms kept; deeper layers fold into
/// the last slot.
pub const N_LAYER_STAGES: usize = 16;

/// Per-stage latency decomposition of the serving path. `request` is
/// the end-to-end histogram the `Report` quantiles come from; the rest
/// split that wall time by where it was actually spent.
#[derive(Debug, Default)]
pub struct StageHistograms {
    /// End-to-end request latency (admission start → completion).
    pub request: LatencyHistogram,
    /// Admission-control wait before enqueueing.
    pub admission: LatencyHistogram,
    /// Queue/batcher residency (enqueued → worker pickup), one sample
    /// per dispatch hop.
    pub queue: LatencyHistogram,
    /// Wire share of traced remote hops: round-trip minus the peer's
    /// own reported queue + compute.
    pub wire: LatencyHistogram,
    /// Backend compute per hop: peer-reported `compute_us` on traced
    /// remote hops, the local backend-call duration otherwise.
    pub compute: LatencyHistogram,
    /// Front-side inter-layer boundary transforms (streams).
    pub boundary: LatencyHistogram,
    /// Whole-hop latency per stream layer (index clamped into
    /// [`N_LAYER_STAGES`]).
    pub layers: [LatencyHistogram; N_LAYER_STAGES],
}

impl StageHistograms {
    pub fn new() -> Self {
        Self::default()
    }

    /// The histogram for stream layer `l` (deep layers fold into the
    /// last slot).
    pub fn layer(&self, l: usize) -> &LatencyHistogram {
        &self.layers[l.min(N_LAYER_STAGES - 1)]
    }

    /// `(label, histogram)` pairs for scrape rendering. The fixed
    /// stages always render; layer slots that never recorded are
    /// skipped (non-stream runs scrape no layer series).
    pub fn labelled(&self) -> Vec<(String, &LatencyHistogram)> {
        let mut v: Vec<(String, &LatencyHistogram)> = vec![
            ("request".into(), &self.request),
            ("admission".into(), &self.admission),
            ("queue".into(), &self.queue),
            ("wire".into(), &self.wire),
            ("compute".into(), &self.compute),
            ("boundary".into(), &self.boundary),
        ];
        for (i, h) in self.layers.iter().enumerate() {
            if h.count() > 0 {
                v.push((format!("layer{i}"), h));
            }
        }
        v
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    /// Jobs a backend failed (answered with an error result) — e.g. a
    /// dropped remote peer. Not counted in `completed`. With failover
    /// this counts *terminal* failures only: a job that fails on one
    /// worker and succeeds on a sibling counts in `retried` and
    /// `completed`, not here.
    pub failed: AtomicU64,
    /// Failover hops: a worker failed a job and the pool re-enqueued it
    /// on a capable sibling. One job can contribute several hops.
    pub retried: AtomicU64,
    /// Requests refused up front by admission control (the client got a
    /// fast `rejected` answer instead of queueing).
    pub shed: AtomicU64,
    pub psums: AtomicU64,
    pub sim_cycles: AtomicU64,
    pub weight_dma_skipped: AtomicU64,
    /// Wire-v4 weight-store hits: hash-only requests served from the
    /// content-addressed store without the blob crossing the wire.
    pub weight_hits: AtomicU64,
    /// Wire-v4 weight-store misses: hash-only requests answered with a
    /// `need_weights` frame (client must re-send the blob inline once).
    pub weight_misses: AtomicU64,
    /// Weight bytes that did *not* cross the wire thanks to store hits.
    pub weight_bytes_saved: AtomicU64,
    /// Weight bytes that *did* arrive inline over the wire (v2/v3 JSON
    /// arrays and v3/v4 binary bodies alike) — the ships-at-most-once
    /// property is asserted against this counter.
    pub wire_weight_bytes: AtomicU64,
    /// Stage-keyed latency decomposition (`stages.request` is the
    /// aggregate histogram earlier revisions kept as `latency`).
    pub stages: StageHistograms,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_completion(&self, psums: u64, cycles: u64, latency: Duration, reused: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.psums.fetch_add(psums, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        if reused {
            self.weight_dma_skipped.fetch_add(1, Ordering::Relaxed);
        }
        self.stages.request.record(latency);
    }

    /// Record a job a backend failed terminally (the pool answered it
    /// with an error result instead of numerics).
    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one failover hop (job re-enqueued on a sibling worker).
    pub fn record_retry(&self) {
        self.retried.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request shed by admission control.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a weight-store hit that kept `bytes` weight bytes off the
    /// wire.
    pub fn record_weight_hit(&self, bytes: u64) {
        self.weight_hits.fetch_add(1, Ordering::Relaxed);
        self.weight_bytes_saved.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record a weight-store miss (a `need_weights` frame went out).
    pub fn record_weight_miss(&self) {
        self.weight_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `bytes` of inline weight payload received over the wire.
    pub fn record_wire_weight_bytes(&self, bytes: u64) {
        self.wire_weight_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Simulated GOPS in the paper's PSUM accounting, given the board
    /// frequency and the number of parallel cores that produced the
    /// cycles (per-core cycles accumulate into `sim_cycles`).
    pub fn sim_gops_psum(&self, freq_hz: u64, n_cores: usize) -> f64 {
        let cycles = self.sim_cycles.load(Ordering::Relaxed);
        if cycles == 0 {
            return 0.0;
        }
        // Wall time = per-core cycles; with even load, per-core ≈ total/n.
        let wall_cycles = cycles as f64 / n_cores as f64;
        self.psums.load(Ordering::Relaxed) as f64 / (wall_cycles / freq_hz as f64) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record(Duration::from_micros(10));
        }
        h.record(Duration::from_millis(100));
        assert_eq!(h.count(), 100);
        assert!(h.quantile_us(0.5) <= 16);
        assert!(h.quantile_us(1.0) >= 100_000 / 2);
    }

    #[test]
    fn quantiles_interpolate_within_the_bucket() {
        let h = LatencyHistogram::new();
        // 100 samples all in bucket [8, 16): the old upper-bound
        // estimate answered 16 for *every* quantile; interpolation
        // spreads ranks across the bucket.
        for _ in 0..100 {
            h.record(Duration::from_micros(10));
        }
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!((8..=12).contains(&p50), "p50={p50}");
        assert!(p50 < p99, "p50={p50} p99={p99}");
        assert!(h.quantile_us(1.0) <= 16);
    }

    #[test]
    fn zero_latency_lands_in_first_bucket() {
        let h = LatencyHistogram::new();
        h.record(Duration::ZERO);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_us(1.0) <= 2);
        assert_eq!(h.sum_us(), 1);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        let combined = LatencyHistogram::new();
        for us in [1u64, 9, 9, 130, 70_000] {
            a.record_us(us);
            combined.record_us(us);
        }
        for us in [3u64, 9, 500_000] {
            b.record_us(us);
            combined.record_us(us);
        }
        a.merge(&b);
        assert_eq!(a.bucket_counts(), combined.bucket_counts());
        assert_eq!(a.count(), combined.count());
        assert_eq!(a.sum_us(), combined.sum_us());
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile_us(q), combined.quantile_us(q), "q={q}");
        }
    }

    #[test]
    fn stage_histograms_label_only_recorded_layers() {
        let s = StageHistograms::new();
        s.request.record_us(100);
        s.layer(2).record_us(40);
        s.layer(99).record_us(7); // folds into the last slot
        let labels: Vec<String> = s.labelled().into_iter().map(|(l, _)| l).collect();
        assert!(labels.contains(&"request".to_string()));
        assert!(labels.contains(&"wire".to_string())); // fixed stages always render
        assert!(labels.contains(&"layer2".to_string()));
        assert!(labels.contains(&format!("layer{}", N_LAYER_STAGES - 1)));
        assert!(!labels.contains(&"layer3".to_string()));
    }

    #[test]
    fn weight_cache_counters_accumulate_independently() {
        let m = Metrics::new();
        m.record_weight_hit(2304);
        m.record_weight_hit(2304);
        m.record_weight_miss();
        m.record_wire_weight_bytes(2304);
        assert_eq!(m.weight_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.weight_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.weight_bytes_saved.load(Ordering::Relaxed), 4608);
        assert_eq!(m.wire_weight_bytes.load(Ordering::Relaxed), 2304);
        // Orthogonal to the completion counters.
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn gops_accounting_single_core() {
        let m = Metrics::new();
        // 2 psums per cycle at 112 MHz -> 0.224 GOPS (paper).
        m.record_completion(2 * 1000, 1000, Duration::from_micros(5), false);
        let gops = m.sim_gops_psum(112_000_000, 1);
        assert!((gops - 0.224).abs() < 1e-9, "{gops}");
    }

    #[test]
    fn gops_scales_with_cores() {
        let m = Metrics::new();
        // Two cores each did 1000 cycles of 2-psum/cycle work.
        m.record_completion(2000, 1000, Duration::from_micros(5), false);
        m.record_completion(2000, 1000, Duration::from_micros(5), false);
        let one = m.sim_gops_psum(112_000_000, 1);
        let two = m.sim_gops_psum(112_000_000, 2);
        assert!((two / one - 2.0).abs() < 1e-9);
    }
}
