//! Experiment S52 scaling: the paper's 0.224 GOPS single-core and
//! 4.48 GOPS 20-core claims, measured end-to-end through the
//! coordinator's backend pool (not just multiplied out) — plus the
//! heterogeneous-pool scenario the backend refactor enables: simulated
//! IP cores mixed with golden-CPU fallback workers serving a trace
//! that includes depthwise (MobileNet-style) jobs.
//!
//! ```bash
//! cargo run --release --example multicore_scaling -- [--requests N]
//! ```
//!
//! Each core count serves the same S52-heavy trace; simulated GOPS is
//! computed from per-core cycle totals. Expect near-linear scaling —
//! cores are independent (separate BRAM sets), as in the paper.

use repro::coordinator::{CoordinatorConfig, Server};
use repro::model::trace::{generate, TraceConfig};
use repro::paper::{GOPS_20, GOPS_SINGLE, MAX_CORES_Z2};
use repro::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[]).map_err(|e| anyhow::anyhow!(e))?;
    let n = args.get_usize("requests", 40).map_err(|e| anyhow::anyhow!(e))?;

    let trace = generate(&TraceConfig {
        n,
        mean_gap_us: 0,
        s52_fraction: 1.0, // pure §5.2 workload
        depthwise_fraction: 0.0,
        seed: 52,
    });

    println!("S52 trace: {n} requests of 224x224x8 (x) 8x3x3x8\n");
    println!(
        "{:>5} {:>14} {:>12} {:>10} {:>12}",
        "cores", "sim GOPS", "vs paper", "host RPS", "p99 (us)"
    );
    let mut results = Vec::new();
    for cores in [1usize, 2, 4, 8, 16, MAX_CORES_Z2] {
        let mut server = Server::new(CoordinatorConfig::default().with_cores(cores));
        let report = server.run_trace(&trace);
        server.shutdown();
        let expected = GOPS_SINGLE * cores as f64;
        println!(
            "{:>5} {:>14.4} {:>11.1}% {:>10.1} {:>12}",
            cores,
            report.sim_gops_psum,
            report.sim_gops_psum / expected * 100.0,
            report.host_rps,
            report.p99_us
        );
        results.push((cores, report.sim_gops_psum));
    }

    let single = results[0].1;
    let twenty = results.last().unwrap().1;
    println!("\npaper: single core {GOPS_SINGLE} GOPS, 20 cores {GOPS_20} GOPS");
    println!("ours:  single core {single:.4} GOPS, 20 cores {twenty:.4} GOPS");
    let lin = twenty / (single * MAX_CORES_Z2 as f64);
    println!("scaling efficiency at 20 cores: {:.1}%", lin * 100.0);

    // --- heterogeneous pool: IP cores + host fallback workers serving
    // mixed standard/depthwise traffic. Depthwise jobs route only to
    // depthwise-capable backends (capability mask); fallback workers
    // absorb overflow once the accelerators queue up (cost-model-
    // weighted least-loaded dispatch). The im2col rows swap the naive
    // golden loops for the threaded im2col+GEMM backend — same
    // bit-exact numerics, far cheaper cost quotes, so the host absorbs
    // more of the spill.
    println!("\n=== heterogeneous pool: mixed standard + depthwise trace ===");
    let mixed = generate(&TraceConfig {
        n: n.max(24),
        mean_gap_us: 0,
        s52_fraction: 0.1,
        depthwise_fraction: 0.3,
        seed: 53,
    });
    let dw_jobs = mixed
        .iter()
        .filter(|e| e.kind == repro::backend::JobKind::Depthwise)
        .count();
    println!(
        "trace: {} requests ({} depthwise), pools below serve the identical stream",
        mixed.len(),
        dw_jobs
    );
    for (label, cores, golden, im2col) in [
        ("4 sim cores          ", 4usize, 0usize, 0usize),
        ("4 sim + 2 golden-cpu ", 4, 2, 0),
        ("2 sim + 4 golden-cpu ", 2, 4, 0),
        ("4 sim + 2 im2col-cpu ", 4, 0, 2),
        ("2 sim + 4 im2col-cpu ", 2, 0, 4),
    ] {
        let mut server = Server::new(
            CoordinatorConfig::default()
                .with_cores(cores)
                .with_golden_workers(golden)
                .with_im2col_workers(im2col),
        );
        let report = server.run_trace(&mixed);
        server.shutdown();
        let mix = report
            .backend_mix
            .iter()
            .map(|(name, count)| format!("{name}:{count}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "  {label} p50={:>6}us p99={:>6}us host_rps={:>7.1} served {mix}",
            report.p50_us, report.p99_us, report.host_rps
        );
    }
    println!("(depthwise jobs never appear on a depthwise-incapable backend; see\n rust/src/coordinator/dispatch.rs tests for the wrap8-core exclusion proof)");
    Ok(())
}
