//! Telemetry: distributed request tracing for the serving path.
//!
//! Every request (or streamed image) gets a non-zero trace id at
//! admission; the front then records one [`SpanRecord`] per serving
//! stage — admission wait, batcher/queue residency, each dispatch hop
//! (worker-tagged, one per failover attempt), the wire round-trip and
//! remote compute split reported by traced v4+ peers, front-side
//! boundary transforms, and per-layer stream hops — into a bounded
//! [`SpanSink`] ring buffer. The sink is std-only and allocation-free
//! on the record path: each slot is a fixed set of atomics guarded by a
//! per-slot sequence word, writers claim slots with one `fetch_add`,
//! and the oldest spans are overwritten when the ring wraps. Snapshots
//! export as Chrome trace-event JSON (`chrome://tracing`, Perfetto) via
//! `--trace-out` on `serve`/`fleet`.
//!
//! Live scraping (Prometheus text exposition over a read-only TCP
//! endpoint) lives in [`scrape`].

pub mod scrape;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Default ring capacity: at ~8 spans per request this holds the last
/// ~8k requests, and the whole ring is ~3 MB of atomics.
pub const DEFAULT_SPAN_CAPACITY: usize = 65_536;

/// Tiling spans are recorded at µs granularity, so a request tree can
/// legitimately leave a few µs of rounding gap per span; coverage
/// validation tolerates this much absolute slack per request.
pub const COVERAGE_SLACK_US: u64 = 100;

/// The serving stage a span describes. `Layer(l)` is a whole
/// (dispatch + boundary) hop of a streamed image's layer chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Per-request root: admission start through completion. Exactly
    /// one per trace id; every other span nests inside it.
    Request,
    /// Admission-control wait (backpressure) before enqueueing.
    Admission,
    /// Batcher/queue residency: enqueued until a worker picked it up.
    Queue,
    /// One dispatch hop on one worker (one span per failover attempt).
    Dispatch,
    /// Wire share of a remote hop: round-trip minus the peer's own
    /// reported queue + compute (only when the peer negotiated trace).
    Wire,
    /// Backend compute: the peer-reported `compute_us` on a traced
    /// remote hop, the local backend-call duration otherwise.
    Compute,
    /// Front-side inter-layer boundary transform of a streamed image.
    Boundary,
    /// One whole layer hop of a streamed image.
    Layer(u16),
}

impl Stage {
    /// Pack into one atomic word: discriminant in the low byte, layer
    /// index above it.
    fn encode(self) -> u64 {
        match self {
            Stage::Request => 1,
            Stage::Admission => 2,
            Stage::Queue => 3,
            Stage::Dispatch => 4,
            Stage::Wire => 5,
            Stage::Compute => 6,
            Stage::Boundary => 7,
            Stage::Layer(l) => 8 | ((l as u64) << 8),
        }
    }

    fn decode(v: u64) -> Option<Stage> {
        match v & 0xff {
            1 => Some(Stage::Request),
            2 => Some(Stage::Admission),
            3 => Some(Stage::Queue),
            4 => Some(Stage::Dispatch),
            5 => Some(Stage::Wire),
            6 => Some(Stage::Compute),
            7 => Some(Stage::Boundary),
            8 => Some(Stage::Layer((v >> 8) as u16)),
            _ => None,
        }
    }

    /// Stable stage label (Chrome trace event names and the Prometheus
    /// `stage` label share it).
    pub fn name(self) -> String {
        match self {
            Stage::Request => "request".into(),
            Stage::Admission => "admission".into(),
            Stage::Queue => "queue".into(),
            Stage::Dispatch => "dispatch".into(),
            Stage::Wire => "wire".into(),
            Stage::Compute => "compute".into(),
            Stage::Boundary => "boundary".into(),
            Stage::Layer(l) => format!("layer{l}"),
        }
    }
}

/// One ring slot: a per-slot seqlock (`seq` odd = mid-write) over plain
/// atomic fields, so writers never block and a reader can detect and
/// skip a slot it raced with. No unsafe, no allocation.
#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    trace: AtomicU64,
    stage: AtomicU64,
    worker: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

/// Bounded lock-free span ring: overwrite-oldest, fixed capacity,
/// shared by every recording thread via `Arc`.
///
/// The record path is a `fetch_add` plus six relaxed/release stores —
/// no locks, no allocation beyond the pre-sized ring. Worker names are
/// interned once per pool construction ([`SpanSink::worker_tag`]), so
/// per-span worker attribution is a plain integer store.
pub struct SpanSink {
    /// All span timestamps are µs offsets from this instant.
    epoch: Instant,
    /// Monotone ticket counter; slot = ticket % capacity.
    cursor: AtomicU64,
    slots: Vec<Slot>,
    /// Interned worker names; a span's `worker` word is 1 + index
    /// (0 = no worker).
    workers: Mutex<Vec<String>>,
}

impl std::fmt::Debug for SpanSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpanSink")
            .field("capacity", &self.slots.len())
            .field("recorded", &self.recorded())
            .finish()
    }
}

impl Default for SpanSink {
    fn default() -> Self {
        Self::new()
    }
}

impl SpanSink {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_SPAN_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        SpanSink {
            epoch: Instant::now(),
            cursor: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Slot::default()).collect(),
            workers: Mutex::new(Vec::new()),
        }
    }

    /// µs since the sink's epoch (the timebase of every span).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// An `Instant` as a µs offset on the sink's timebase (zero for
    /// instants predating the sink).
    pub fn offset_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Intern `name`, returning its span tag. Called once per worker at
    /// pool construction (or once per batch), never per span — the hot
    /// path stores the returned integer only.
    pub fn worker_tag(&self, name: &str) -> u64 {
        let mut w = self.workers.lock().unwrap();
        if let Some(i) = w.iter().position(|n| n == name) {
            return (i + 1) as u64;
        }
        w.push(name.to_string());
        w.len() as u64
    }

    /// Record one span. `trace == 0` means tracing is off for this
    /// request and the call is a no-op; `worker == 0` means no worker
    /// attribution.
    pub fn record(&self, trace: u64, stage: Stage, worker: u64, start_us: u64, dur_us: u64) {
        if trace == 0 {
            return;
        }
        let ticket = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        // Odd seq marks the slot mid-write; the final even store
        // publishes it. A reader that observes either an odd value or
        // a seq change across its field reads skips the slot. (Two
        // writers a full ring-wrap apart could interleave on one slot;
        // with a 65k ring that window is vanishingly small and costs
        // one garbled debug span, never memory safety.)
        slot.seq.store(2 * ticket + 1, Ordering::Release);
        slot.trace.store(trace, Ordering::Relaxed);
        slot.stage.store(stage.encode(), Ordering::Relaxed);
        slot.worker.store(worker, Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.dur_us.store(dur_us, Ordering::Relaxed);
        slot.seq.store(2 * ticket + 2, Ordering::Release);
    }

    /// Record a span from two instants on the sink's timebase.
    pub fn span(&self, trace: u64, stage: Stage, worker: u64, start: Instant, end: Instant) {
        let s = self.offset_us(start);
        let e = self.offset_us(end);
        self.record(trace, stage, worker, s, e.saturating_sub(s));
    }

    /// Total spans ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Spans lost to ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    /// Consistent copy of every published span, ordered by
    /// (trace, start). Slots mid-write or overwritten during the read
    /// are skipped, never torn.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let names = self.workers.lock().unwrap().clone();
        let mut out = Vec::new();
        for slot in &self.slots {
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == 0 || seq % 2 == 1 {
                continue;
            }
            let trace = slot.trace.load(Ordering::Relaxed);
            let stage = slot.stage.load(Ordering::Relaxed);
            let worker = slot.worker.load(Ordering::Relaxed);
            let start_us = slot.start_us.load(Ordering::Relaxed);
            let dur_us = slot.dur_us.load(Ordering::Relaxed);
            if slot.seq.load(Ordering::Acquire) != seq {
                continue; // overwritten mid-read
            }
            let Some(stage) = Stage::decode(stage) else {
                continue;
            };
            let worker = (worker > 0)
                .then(|| names.get(worker as usize - 1).cloned())
                .flatten();
            out.push(SpanRecord {
                trace,
                stage,
                worker,
                start_us,
                dur_us,
            });
        }
        out.sort_by(|a, b| {
            (a.trace, a.start_us, a.dur_us, a.stage).cmp(&(b.trace, b.start_us, b.dur_us, b.stage))
        });
        out
    }

    /// Chrome trace-event JSON (the array form): one complete (`"X"`)
    /// event per span, `tid` = trace id so each request renders as its
    /// own nested track in `chrome://tracing` / Perfetto.
    pub fn to_chrome_trace(&self) -> String {
        let events: Vec<Json> = self
            .snapshot()
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("name", Json::str(r.stage.name())),
                    ("ph", Json::str("X")),
                    ("pid", Json::uint(1)),
                    ("tid", Json::uint(r.trace)),
                    ("ts", Json::uint(r.start_us)),
                    ("dur", Json::uint(r.dur_us)),
                ];
                if let Some(w) = &r.worker {
                    fields.push(("args", Json::obj(vec![("worker", Json::str(w.clone()))])));
                }
                Json::obj(fields)
            })
            .collect();
        Json::Arr(events).to_json()
    }
}

/// One decoded span from a [`SpanSink`] snapshot.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub trace: u64,
    pub stage: Stage,
    pub worker: Option<String>,
    pub start_us: u64,
    pub dur_us: u64,
}

/// Summary of a validated trace snapshot.
#[derive(Clone, Copy, Debug)]
pub struct TraceCheck {
    /// Number of distinct request roots.
    pub roots: usize,
    /// The worst per-request child coverage fraction observed.
    pub worst_coverage: f64,
}

/// Validate the span-tree contract over a snapshot: every trace id has
/// exactly one [`Stage::Request`] root, and the union of its child
/// spans (clipped to the root window) covers ≥ 99% of the root's wall
/// time (with [`COVERAGE_SLACK_US`] absolute slack for µs rounding).
pub fn validate_coverage(records: &[SpanRecord]) -> Result<TraceCheck, String> {
    use std::collections::BTreeMap;
    let mut by_trace: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    for r in records {
        by_trace.entry(r.trace).or_default().push(r);
    }
    let mut roots = 0usize;
    let mut worst = 1.0f64;
    for (trace, spans) in &by_trace {
        let n_roots = spans.iter().filter(|s| s.stage == Stage::Request).count();
        if n_roots != 1 {
            return Err(format!("trace {trace} has {n_roots} request roots, want 1"));
        }
        roots += 1;
        let root = spans.iter().find(|s| s.stage == Stage::Request).unwrap();
        let (lo, hi) = (root.start_us, root.start_us + root.dur_us);
        let mut ivs: Vec<(u64, u64)> = spans
            .iter()
            .filter(|s| s.stage != Stage::Request)
            .map(|s| (s.start_us.max(lo), (s.start_us + s.dur_us).min(hi)))
            .filter(|(a, b)| b > a)
            .collect();
        ivs.sort_unstable();
        let mut covered = 0u64;
        let mut cur: Option<(u64, u64)> = None;
        for (a, b) in ivs {
            match &mut cur {
                Some((_, ce)) if a <= *ce => *ce = (*ce).max(b),
                _ => {
                    if let Some((cs, ce)) = cur {
                        covered += ce - cs;
                    }
                    cur = Some((a, b));
                }
            }
        }
        if let Some((cs, ce)) = cur {
            covered += ce - cs;
        }
        let total = hi - lo;
        let frac = if total == 0 {
            1.0
        } else {
            covered as f64 / total as f64
        };
        if frac < 0.99 && total.saturating_sub(covered) > COVERAGE_SLACK_US {
            return Err(format!(
                "trace {trace}: child spans cover {covered} of {total}us ({:.2}%) of the request root",
                frac * 100.0
            ));
        }
        worst = worst.min(frac);
    }
    Ok(TraceCheck {
        roots,
        worst_coverage: worst,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot_round_trip() {
        let sink = SpanSink::with_capacity(16);
        let w = sink.worker_tag("sim-ipcore-i32");
        sink.record(7, Stage::Request, 0, 100, 50);
        sink.record(7, Stage::Dispatch, w, 110, 30);
        let spans = sink.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].trace, 7);
        assert_eq!(spans[0].stage, Stage::Request);
        assert_eq!(spans[0].worker, None);
        assert_eq!(spans[1].stage, Stage::Dispatch);
        assert_eq!(spans[1].worker.as_deref(), Some("sim-ipcore-i32"));
        assert_eq!((spans[1].start_us, spans[1].dur_us), (110, 30));
    }

    #[test]
    fn trace_zero_is_a_no_op() {
        let sink = SpanSink::with_capacity(8);
        sink.record(0, Stage::Queue, 0, 1, 1);
        assert_eq!(sink.recorded(), 0);
        assert!(sink.snapshot().is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let sink = SpanSink::with_capacity(4);
        for i in 1..=10u64 {
            sink.record(i, Stage::Queue, 0, i, 1);
        }
        assert_eq!(sink.recorded(), 10);
        assert_eq!(sink.dropped(), 6);
        let spans = sink.snapshot();
        assert_eq!(spans.len(), 4);
        // Only the newest four survive.
        let ids: Vec<u64> = spans.iter().map(|s| s.trace).collect();
        assert_eq!(ids, vec![7, 8, 9, 10]);
    }

    #[test]
    fn worker_tags_intern_stably() {
        let sink = SpanSink::new();
        let a = sink.worker_tag("a");
        let b = sink.worker_tag("b");
        assert_ne!(a, b);
        assert_eq!(sink.worker_tag("a"), a);
    }

    #[test]
    fn layer_stages_encode_their_index() {
        for l in [0u16, 1, 15, 300] {
            let enc = Stage::Layer(l).encode();
            assert_eq!(Stage::decode(enc), Some(Stage::Layer(l)));
        }
        assert_eq!(Stage::decode(Stage::Wire.encode()), Some(Stage::Wire));
        assert_eq!(Stage::decode(0), None);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_complete_events() {
        let sink = SpanSink::with_capacity(8);
        let w = sink.worker_tag("golden-cpu");
        sink.record(1, Stage::Request, 0, 0, 100);
        sink.record(1, Stage::Compute, w, 10, 80);
        let parsed = Json::parse(&sink.to_chrome_trace()).expect("chrome trace parses");
        let events = parsed.as_arr().expect("array form");
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.get(&["ph"]).unwrap().as_str(), Some("X"));
            assert!(e.get(&["ts"]).is_some() && e.get(&["dur"]).is_some());
            assert_eq!(e.get(&["tid"]).unwrap().as_u64(), Some(1));
        }
        assert_eq!(
            events[1].get(&["args", "worker"]).unwrap().as_str(),
            Some("golden-cpu")
        );
    }

    #[test]
    fn validate_accepts_a_tiled_tree_and_rejects_gaps() {
        // Tiled: admission [0,10) + queue [10,40) + dispatch [40,100).
        let ok = vec![
            SpanRecord {
                trace: 1,
                stage: Stage::Request,
                worker: None,
                start_us: 0,
                dur_us: 100_000,
            },
            SpanRecord {
                trace: 1,
                stage: Stage::Admission,
                worker: None,
                start_us: 0,
                dur_us: 10_000,
            },
            SpanRecord {
                trace: 1,
                stage: Stage::Queue,
                worker: None,
                start_us: 10_000,
                dur_us: 30_000,
            },
            SpanRecord {
                trace: 1,
                stage: Stage::Dispatch,
                worker: None,
                start_us: 40_000,
                dur_us: 60_000,
            },
        ];
        let check = validate_coverage(&ok).expect("tiled tree validates");
        assert_eq!(check.roots, 1);
        assert!(check.worst_coverage >= 0.99);

        // A 30% hole in the middle must fail.
        let mut gappy = ok.clone();
        gappy[2].dur_us = 1_000;
        let err = validate_coverage(&gappy).unwrap_err();
        assert!(err.contains("cover"), "unexpected error: {err}");

        // A missing root must fail.
        let rootless = vec![ok[1].clone()];
        assert!(validate_coverage(&rootless).is_err());
    }

    #[test]
    fn validate_tolerates_microsecond_rounding_slack() {
        // 99us uncovered out of 5ms is < the absolute slack even though
        // the fraction bar alone would pass anyway; shrink the root so
        // the fraction fails but slack saves it.
        let spans = vec![
            SpanRecord {
                trace: 3,
                stage: Stage::Request,
                worker: None,
                start_us: 0,
                dur_us: 1_000,
            },
            SpanRecord {
                trace: 3,
                stage: Stage::Dispatch,
                worker: None,
                start_us: 60,
                dur_us: 940,
            },
        ];
        // 60us gap of 1000us = 94% coverage, but 60 <= 100us slack.
        let check = validate_coverage(&spans).expect("slack absorbs µs gaps");
        assert_eq!(check.roots, 1);
    }

    #[test]
    fn concurrent_writers_never_tear_a_published_slot() {
        use std::sync::Arc;
        let sink = Arc::new(SpanSink::with_capacity(64));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let sink = Arc::clone(&sink);
                s.spawn(move || {
                    for i in 0..1000u64 {
                        // start == dur == trace so a torn slot is
                        // detectable in the snapshot below.
                        let v = t * 10_000 + i + 1;
                        sink.record(v, Stage::Queue, 0, v, v);
                    }
                });
            }
        });
        assert_eq!(sink.recorded(), 4000);
        for span in sink.snapshot() {
            assert_eq!(span.start_us, span.trace);
            assert_eq!(span.dur_us, span.trace);
        }
    }
}
