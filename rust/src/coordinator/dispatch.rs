//! Core pool: N simulated IP cores as worker threads, fed closed
//! batches; the paper's "deploy up to 20 cores concurrently" (§5.1).
//!
//! Dispatch policy is least-loaded (by queued PSUMs): big S52 layers
//! and small edge-CNN layers coexist in one trace, and PSUM-weighted
//! load balancing is what keeps 20 cores busy instead of FIFO striping.

use super::batcher::Batch;
use super::metrics::Metrics;
use super::request::ConvResult;
use crate::hw::{IpCore, IpCoreConfig};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

enum WorkerMsg {
    Run(Batch),
    Shutdown,
}

struct Worker {
    tx: Sender<WorkerMsg>,
    handle: JoinHandle<()>,
    /// Outstanding simulated work (PSUMs), for least-loaded dispatch.
    load: Arc<AtomicI64>,
}

/// Pool of simulated IP cores.
pub struct CorePool {
    workers: Vec<Worker>,
    pub metrics: Arc<Metrics>,
    config: IpCoreConfig,
}

impl CorePool {
    pub fn new(n_cores: usize, config: IpCoreConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let workers = (0..n_cores)
            .map(|core_idx| Self::spawn_worker(core_idx, config, Arc::clone(&metrics)))
            .collect();
        CorePool {
            workers,
            metrics,
            config,
        }
    }

    pub fn n_cores(&self) -> usize {
        self.workers.len()
    }

    pub fn ip_config(&self) -> IpCoreConfig {
        self.config
    }

    fn spawn_worker(core_idx: usize, config: IpCoreConfig, metrics: Arc<Metrics>) -> Worker {
        let (tx, rx) = channel::<WorkerMsg>();
        let load = Arc::new(AtomicI64::new(0));
        let load_in_worker = Arc::clone(&load);
        let handle = std::thread::Builder::new()
            .name(format!("ipcore-{core_idx}"))
            .spawn(move || {
                let mut core = IpCore::new(config);
                let mut resident_weights: Option<u64> = None;
                while let Ok(WorkerMsg::Run(batch)) = rx.recv() {
                    // Weight-stationary across the batch: first job pays
                    // the weight DMA, the rest reuse the BRAM contents.
                    let batch_weights = batch.weights_id;
                    for sub in batch.jobs {
                        let reused = resident_weights == Some(batch_weights);
                        let run = core
                            .run_layer(
                                &sub.job.spec,
                                &sub.job.img,
                                &sub.job.weights,
                                &sub.job.bias,
                                None,
                            )
                            .expect("batched job passed shape validation at submit");
                        resident_weights = Some(batch_weights);

                        let mut cycles = run.cycles;
                        if reused {
                            // The weight portion of DmaIn is skipped; image
                            // bytes still move. Approximate by the weight
                            // fraction of the input transfer.
                            let w_bytes = sub.job.weights.len() as u64;
                            let total_in = (sub.job.img.len() + sub.job.weights.len()) as u64
                                + 4 * sub.job.bias.len() as u64;
                            let saved = cycles.dma_in * w_bytes / total_in.max(1);
                            cycles.dma_in -= saved;
                            if core.config.count_dma {
                                cycles.total -= saved;
                            }
                        }

                        let latency = sub.enqueued.elapsed();
                        metrics.record_completion(
                            sub.job.spec.psums(),
                            cycles.total.max(cycles.compute),
                            latency,
                            reused,
                        );
                        load_in_worker
                            .fetch_sub(sub.job.spec.psums() as i64, Ordering::Relaxed);
                        // Receiver may have hung up (fire-and-forget); fine.
                        let _ = sub.reply.send(ConvResult {
                            id: sub.job.id,
                            spec: sub.job.spec,
                            output: run.output.as_i32(),
                            cycles,
                            core: core_idx,
                            latency,
                            weights_reused: reused,
                        });
                    }
                }
            })
            .expect("spawn ipcore worker");
        Worker { tx, handle, load }
    }

    /// Dispatch a closed batch to the least-loaded core.
    pub fn dispatch(&self, batch: Batch) {
        let total: i64 = batch
            .jobs
            .iter()
            .map(|s| s.job.spec.psums() as i64)
            .sum();
        let worker = self
            .workers
            .iter()
            .min_by_key(|w| w.load.load(Ordering::Relaxed))
            .expect("pool has at least one core");
        worker.load.fetch_add(total, Ordering::Relaxed);
        self.metrics
            .requests
            .fetch_add(batch.jobs.len() as u64, Ordering::Relaxed);
        worker
            .tx
            .send(WorkerMsg::Run(batch))
            .expect("worker alive while pool alive");
    }

    /// Graceful shutdown: drain queues, join threads.
    pub fn shutdown(self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in self.workers {
            let _ = w.handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::Batch;
    use crate::coordinator::request::{ConvJob, Submission};
    use crate::model::{golden, QUICKSTART};
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn one_job_batch(id: u64) -> (Batch, std::sync::mpsc::Receiver<ConvResult>) {
        let (tx, rx) = channel();
        let job = ConvJob::synthetic(id, QUICKSTART, id);
        let weights_id = job.weights_id;
        (
            Batch {
                spec: QUICKSTART,
                weights_id,
                jobs: vec![Submission {
                    job,
                    reply: tx,
                    enqueued: std::time::Instant::now(),
                }],
            },
            rx,
        )
    }

    #[test]
    fn pool_computes_correct_results() {
        let pool = CorePool::new(2, IpCoreConfig::default());
        let (batch, rx) = one_job_batch(1);
        let job = ConvJob::synthetic(1, QUICKSTART, 1);
        pool.dispatch(batch);
        let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let want = golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false);
        assert_eq!(res.output.data(), want.data());
        assert_eq!(res.id, 1);
        pool.shutdown();
    }

    #[test]
    fn batch_reuses_weights_after_first() {
        let pool = CorePool::new(1, IpCoreConfig::default());
        let (tx, rx) = channel();
        let jobs: Vec<Submission> = (0..3)
            .map(|i| Submission {
                job: ConvJob::synthetic(i, QUICKSTART, i),
                reply: tx.clone(),
                enqueued: std::time::Instant::now(),
            })
            .collect();
        let weights_id = jobs[0].job.weights_id;
        pool.dispatch(Batch {
            spec: QUICKSTART,
            weights_id,
            jobs,
        });
        let results: Vec<ConvResult> = (0..3)
            .map(|_| rx.recv_timeout(Duration::from_secs(10)).unwrap())
            .collect();
        assert!(!results[0].weights_reused);
        assert!(results[1].weights_reused);
        assert!(results[2].weights_reused);
        pool.shutdown();
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let pool = CorePool::new(4, IpCoreConfig::default());
        let (tx, rx) = channel();
        let n = 32u64;
        for i in 0..n {
            let job = ConvJob::synthetic(i, QUICKSTART, i);
            let weights_id = job.weights_id;
            pool.dispatch(Batch {
                spec: QUICKSTART,
                weights_id,
                jobs: vec![Submission {
                    job,
                    reply: tx.clone(),
                    enqueued: std::time::Instant::now(),
                }],
            });
        }
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        pool.shutdown();
    }

    #[test]
    fn metrics_accumulate() {
        let pool = CorePool::new(1, IpCoreConfig::default());
        let (batch, rx) = one_job_batch(5);
        pool.dispatch(batch);
        let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(
            pool.metrics
                .completed
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            pool.metrics.psums.load(std::sync::atomic::Ordering::Relaxed),
            QUICKSTART.psums()
        );
        pool.shutdown();
    }
}
