//! Experiment T1 (DESIGN.md §4): the resource model regenerates the
//! paper's Table 1 within tolerance, and the derived max-cores analysis
//! behind the 20-core / 4.48 GOPS claim is internally consistent.

use repro::hw::device::TABLE1_DEVICES;
use repro::hw::resource::{estimate, max_cores, render_table1, table1, PAPER_TABLE1};

#[test]
fn all_rows_within_5_percent_and_1_mhz() {
    for (e, paper) in table1().iter().zip(PAPER_TABLE1.iter()) {
        assert_eq!(e.device.name, paper.device);
        let lut_err = (e.luts as f64 - paper.luts as f64).abs() / paper.luts as f64;
        let ff_err = (e.ffs as f64 - paper.ffs as f64).abs() / paper.ffs as f64;
        assert!(lut_err < 0.05, "{}: LUTs {} vs paper {}", paper.device, e.luts, paper.luts);
        assert!(ff_err < 0.05, "{}: FFs {} vs paper {}", paper.device, e.ffs, paper.ffs);
        assert!(
            (e.fmax_mhz - paper.fmax_mhz).abs() < 1.0,
            "{}: fmax {} vs paper {}",
            paper.device,
            e.fmax_mhz,
            paper.fmax_mhz
        );
    }
}

#[test]
fn calibration_row_within_1_percent() {
    let e = estimate(&TABLE1_DEVICES[0]);
    let p = PAPER_TABLE1[0];
    assert!((e.luts as f64 - p.luts as f64).abs() / (p.luts as f64) < 0.01);
    assert!((e.ffs as f64 - p.ffs as f64).abs() / (p.ffs as f64) < 0.01);
}

#[test]
fn fmax_ordering_matches_paper() {
    // clg484 < clg400 < zu3eg, as in Table 1.
    let rows = table1();
    assert!(rows[1].fmax_mhz < rows[0].fmax_mhz);
    assert!(rows[0].fmax_mhz < rows[2].fmax_mhz);
}

#[test]
fn utilisation_percentages_match_paper_print() {
    // The paper prints 9.45% / 4.66% etc.; with our estimates the same
    // formula must land within 0.25 percentage points.
    let expected = [(9.45, 4.66), (9.86, 4.75), (16.89, 10.29)];
    for (e, (lut_pct, ff_pct)) in table1().iter().zip(expected) {
        assert!((e.lut_pct - lut_pct).abs() < 0.5, "{} lut%", e.device.name);
        assert!((e.ff_pct - ff_pct).abs() < 0.5, "{} ff%", e.device.name);
    }
}

#[test]
fn twenty_core_claim_analysis() {
    // The paper: "<5% resources ... up to 20 cores". By FFs that holds
    // (4.66% x 20 = 93%); by Table 1's own LUT row the full IP core
    // binds at 10. Both facts must come out of the model.
    let m = max_cores(&TABLE1_DEVICES[0]);
    assert!(m.by_ff >= 20, "FF headroom supports the paper's claim");
    assert_eq!(m.by_lut, 10, "LUT row binds at 10 replicas");
}

#[test]
fn rendered_table_is_complete() {
    let t = render_table1();
    for row in PAPER_TABLE1 {
        assert!(t.contains(row.device));
    }
    assert!(t.contains("MHz"));
}
