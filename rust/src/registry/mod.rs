//! Model registry: named manifests of `model_id → ordered layers`,
//! each layer carrying its spec, kind, weight tensor and
//! content-address (FNV-1a byte hash of the weights).
//!
//! The registry is the client side of multi-tenant serving: instead of
//! shipping raw tensors per request, a tenant submits
//! `(model, layer, input)` and the layer's weights are resolved from
//! the manifest — always the *same bytes*, hence the same
//! `weights_hash`, hence (over wire v4) shipped to a peer at most once
//! per peer lifetime and served from its [`crate::store::WeightStore`]
//! thereafter. The built-in manifest set is deterministic from a seed:
//! model 0 is the repo's MobileNet-lite
//! ([`crate::model::mobilenet::MobileNetLite`]), lowered exactly the
//! way `infer_sim` lowers it (depthwise 3×3 blocks plus pointwise
//! layers pre-lowered to the padded-3×3 dataflow), and models 1..N are
//! synthetic tenants over trace-library shapes
//! ([`crate::model::trace`]) with per-model weight sets.
//!
//! Everything here is ordinary `ConvJob` construction — the registry
//! changes *where tensors come from*, never what the backends compute,
//! so the parity contract (`rust/tests/backend_parity.rs`) covers
//! registry-built jobs like any others.

use crate::backend::JobKind;
use crate::coordinator::request::{
    fnv1a_bytes, weights_fingerprint_salted, ConvJob,
};
use crate::hw::depthwise::pointwise_as_3x3;
use crate::hw::AccumMode;
use crate::model::mobilenet::{mobilenet_lite_specs, MobileNetLite};
use crate::model::{LayerSpec, Tensor};
use crate::util::prng::Prng;

/// One layer of a manifest: everything needed to build a `ConvJob`
/// except the input image.
#[derive(Clone)]
pub struct LayerParams {
    pub spec: LayerSpec,
    pub kind: JobKind,
    pub weights: std::sync::Arc<Tensor<u8>>,
    pub bias: std::sync::Arc<Vec<i32>>,
    /// Content address: FNV-1a over the raw weight bytes — the wire
    /// v4 `weights_hash` and the [`crate::store::WeightStore`] key.
    pub weights_hash: u64,
}

impl LayerParams {
    fn new(spec: LayerSpec, kind: JobKind, weights: Tensor<u8>, bias: Vec<i32>) -> Self {
        let weights_hash = fnv1a_bytes(weights.data());
        LayerParams {
            spec,
            kind,
            weights: std::sync::Arc::new(weights),
            bias: std::sync::Arc::new(bias),
            weights_hash,
        }
    }
}

/// One model: an id and its ordered layers.
pub struct ModelManifest {
    pub id: String,
    pub layers: Vec<LayerParams>,
}

/// The registry: every model this process can serve requests for.
pub struct ModelRegistry {
    models: Vec<ModelManifest>,
}

/// Synthetic-tenant layer library: paper-compatible standard shapes
/// plus one depthwise, echoing the trace generator's mix so synthetic
/// tenants stress the same routing paths as `model/trace.rs` traffic.
fn synthetic_layer_specs() -> Vec<(LayerSpec, JobKind)> {
    vec![
        (LayerSpec::new(8, 16, 16, 8), JobKind::Standard),
        (LayerSpec::new(4, 12, 12, 8), JobKind::Standard),
        (LayerSpec::new(8, 15, 15, 8), JobKind::Depthwise),
    ]
}

impl ModelRegistry {
    /// The built-in manifest set: `n_models` deterministic models from
    /// `seed`. Model 0 is MobileNet-lite (its blocks lowered to the
    /// depthwise + pointwise-as-3×3 job kinds the core serves); models
    /// 1.. are synthetic tenants, each with its own weight set (so
    /// distinct tenants never alias in the weight store).
    pub fn builtin(n_models: usize, seed: u64) -> Self {
        assert!(n_models >= 1, "a registry serves at least one model");
        let mut models = Vec::with_capacity(n_models);
        let net = MobileNetLite::new(seed);
        let mut layers = Vec::new();
        for b in &net.blocks {
            // Depthwise 3×3 (+fused ReLU), exactly as infer_sim runs it.
            let dw_spec =
                LayerSpec::new(b.spec.c, b.spec.h, b.spec.w, b.spec.c).with_relu();
            layers.push(LayerParams::new(
                dw_spec,
                JobKind::Depthwise,
                b.dw.clone(),
                b.dw_bias.clone(),
            ));
            // Pointwise 1×1 pre-lowered to the padded-3×3 dataflow: the
            // stored weights are already the centre-tapped (K,C,3,3)
            // tensor, so a registry job is explicit tensors on the wire.
            let pw_spec = LayerSpec::new(
                b.spec.c,
                b.spec.dw_oh() + 2,
                b.spec.dw_ow() + 2,
                b.spec.k,
            );
            layers.push(LayerParams::new(
                pw_spec,
                JobKind::PointwiseAs3x3,
                pointwise_as_3x3(&b.pw),
                b.pw_bias.clone(),
            ));
        }
        models.push(ModelManifest {
            id: "mobilenet-lite".to_string(),
            layers,
        });
        for m in 1..n_models {
            // Per-model weight stream: tenants must not share bytes, or
            // the store could not tell their residency apart.
            let mut rng = Prng::new(seed ^ (m as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let layers = synthetic_layer_specs()
                .into_iter()
                .map(|(spec, kind)| {
                    let weight_len = match kind {
                        JobKind::Depthwise => spec.c * 9,
                        _ => spec.k * spec.c * 9,
                    };
                    let shape: Vec<usize> = match kind {
                        JobKind::Depthwise => vec![spec.c, 3, 3],
                        _ => vec![spec.k, spec.c, 3, 3],
                    };
                    let out_ch = match kind {
                        JobKind::Depthwise => spec.c,
                        _ => spec.k,
                    };
                    let weights =
                        Tensor::from_vec(&shape, rng.bytes_below(weight_len, 16));
                    let bias: Vec<i32> =
                        (0..out_ch).map(|_| rng.range_i64(0, 32) as i32).collect();
                    LayerParams::new(spec, kind, weights, bias)
                })
                .collect();
            models.push(ModelManifest {
                id: format!("synthetic-{m}"),
                layers,
            });
        }
        ModelRegistry { models }
    }

    pub fn models(&self) -> &[ModelManifest] {
        &self.models
    }

    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    pub fn n_layers(&self, model_idx: usize) -> usize {
        self.models.get(model_idx).map_or(0, |m| m.layers.len())
    }

    /// Look a manifest up by id (the client-facing key).
    pub fn manifest(&self, id: &str) -> Option<&ModelManifest> {
        self.models.iter().find(|m| m.id == id)
    }

    /// Distinct weight blobs across every model — the number of
    /// inline weight ships a cold v4 peer should see at most.
    pub fn distinct_weight_hashes(&self) -> usize {
        let mut hashes: Vec<u64> = self
            .models
            .iter()
            .flat_map(|m| m.layers.iter().map(|l| l.weights_hash))
            .collect();
        hashes.sort_unstable();
        hashes.dedup();
        hashes.len()
    }

    /// Deterministic multi-tenant request mix: request `i` round-robins
    /// across models (maximal tenant interleave — the hard case for a
    /// weight cache) and draws its layer from a per-request Prng.
    pub fn pick(&self, i: u64, seed: u64) -> (usize, usize) {
        let model = (i % self.models.len() as u64) as usize;
        let layer = Prng::new(seed ^ (i << 1)).below(self.models[model].layers.len() as u64)
            as usize;
        (model, layer)
    }

    /// Build the `ConvJob` for one `(model, layer, input)` submission:
    /// manifest weights + a deterministic synthetic input image from
    /// `input_seed`. The weight fingerprint is derived from the actual
    /// bytes exactly like the wire's explicit-tensor path, so batching
    /// and DMA reuse treat registry jobs identically.
    pub fn job(
        &self,
        model_idx: usize,
        layer_idx: usize,
        job_id: u64,
        input_seed: u64,
    ) -> anyhow::Result<ConvJob> {
        let model = self
            .models
            .get(model_idx)
            .ok_or_else(|| anyhow::anyhow!("no model {model_idx} in the registry"))?;
        let layer = model.layers.get(layer_idx).ok_or_else(|| {
            anyhow::anyhow!("model {} has no layer {layer_idx}", model.id)
        })?;
        let spec = layer.spec;
        let mut rng = Prng::new(input_seed);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        );
        Ok(ConvJob {
            id: job_id,
            spec,
            kind: layer.kind,
            accum: AccumMode::I32,
            img,
            weights: (*layer.weights).clone(),
            bias: (*layer.bias).clone(),
            weights_id: weights_fingerprint_salted(&spec, layer.kind, layer.weights_hash),
            weights_hash: layer.weights_hash,
            wire_weights_cached: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::depthwise::golden_depthwise3x3;
    use crate::model::golden;

    #[test]
    fn builtin_registry_is_deterministic() {
        let a = ModelRegistry::builtin(3, 42);
        let b = ModelRegistry::builtin(3, 42);
        assert_eq!(a.n_models(), 3);
        for (ma, mb) in a.models().iter().zip(b.models()) {
            assert_eq!(ma.id, mb.id);
            for (la, lb) in ma.layers.iter().zip(&mb.layers) {
                assert_eq!(la.weights_hash, lb.weights_hash);
                assert_eq!(la.weights.data(), lb.weights.data());
            }
        }
        // A different seed is a different weight universe.
        let c = ModelRegistry::builtin(3, 43);
        assert_ne!(
            a.models()[0].layers[0].weights_hash,
            c.models()[0].layers[0].weights_hash
        );
    }

    #[test]
    fn mobilenet_manifest_lowers_every_block_to_served_kinds() {
        let reg = ModelRegistry::builtin(1, 7);
        let m = reg.manifest("mobilenet-lite").expect("built-in model");
        let specs = mobilenet_lite_specs();
        assert_eq!(m.layers.len(), specs.len() * 2);
        for (i, b) in specs.iter().enumerate() {
            let dw = &m.layers[2 * i];
            assert_eq!(dw.kind, JobKind::Depthwise);
            assert_eq!((dw.spec.c, dw.spec.k), (b.c, b.c));
            assert!(dw.spec.relu, "mobilenet depthwise fuses ReLU");
            let pw = &m.layers[2 * i + 1];
            assert_eq!(pw.kind, JobKind::PointwiseAs3x3);
            assert_eq!((pw.spec.c, pw.spec.k), (b.c, b.k));
            assert_eq!(pw.spec.h, b.dw_oh() + 2, "pre-padded for the 3x3 dataflow");
            assert_eq!(pw.weights.shape(), &[b.k, b.c, 3, 3]);
        }
    }

    #[test]
    fn tenants_never_share_weight_hashes() {
        let reg = ModelRegistry::builtin(4, 11);
        let total: usize = reg.models().iter().map(|m| m.layers.len()).sum();
        assert_eq!(
            reg.distinct_weight_hashes(),
            total,
            "every layer of every tenant must have its own content address"
        );
    }

    #[test]
    fn registry_jobs_share_weights_across_requests_and_match_golden() {
        let reg = ModelRegistry::builtin(2, 5);
        // Two requests for the same layer: different inputs, identical
        // weight identity — the whole point of the registry.
        let a = reg.job(0, 0, 1, 100).unwrap();
        let b = reg.job(0, 0, 2, 200).unwrap();
        assert_eq!(a.weights_hash, b.weights_hash);
        assert_eq!(a.weights_id, b.weights_id);
        assert_ne!(a.img.data(), b.img.data());
        // Depthwise layer 0 is bit-exact against the golden reference.
        let want = golden_depthwise3x3(&a.img, &a.weights, &a.bias, a.spec.relu);
        assert_eq!(a.kind, JobKind::Depthwise);
        assert!(want.data().iter().any(|&v| v != 0));
        // A standard synthetic-tenant layer matches the raw conv.
        let s = reg.job(1, 0, 3, 300).unwrap();
        assert_eq!(s.kind, JobKind::Standard);
        let want_s = golden::conv3x3_i32(&s.img, &s.weights, &s.bias, false);
        assert_eq!(want_s.shape(), &[s.spec.k, s.spec.conv_oh(), s.spec.conv_ow()]);
    }

    #[test]
    fn job_rejects_out_of_range_submissions() {
        let reg = ModelRegistry::builtin(1, 3);
        assert!(reg.job(1, 0, 1, 1).is_err(), "unknown model");
        assert!(reg.job(0, 99, 1, 1).is_err(), "unknown layer");
    }

    #[test]
    fn pick_is_deterministic_and_covers_every_model() {
        let reg = ModelRegistry::builtin(3, 9);
        let mut seen = [false; 3];
        for i in 0..12u64 {
            let (m, l) = reg.pick(i, 17);
            assert_eq!((m, l), reg.pick(i, 17));
            assert!(l < reg.n_layers(m));
            seen[m] = true;
        }
        assert!(seen.iter().all(|&s| s), "round-robin touches every tenant");
    }
}
