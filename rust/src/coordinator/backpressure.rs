//! Admission control / backpressure for the serving path.
//!
//! The simulated IP cores are a fixed-capacity resource; an open-loop
//! client can queue unbounded work and blow latency through the roof.
//! The admission controller bounds *in-flight simulated work* (measured
//! in PSUMs, the same unit the dispatcher balances by) and offers the
//! two standard policies: reject-on-full (load shedding, the serving
//! answer) and block-until-drained (batch/offline answer).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// What to do when the in-flight budget is exhausted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Refuse new work immediately (caller sees `Rejected`).
    Reject,
    /// Block the submitting thread until capacity frees up.
    Block,
}

/// Admission decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    Rejected,
}

/// Bounded in-flight work counter.
#[derive(Debug)]
pub struct AdmissionController {
    max_inflight_psums: u64,
    inflight: Mutex<u64>,
    freed: Condvar,
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
}

impl AdmissionController {
    pub fn new(max_inflight_psums: u64) -> Self {
        AdmissionController {
            max_inflight_psums,
            inflight: Mutex::new(0),
            freed: Condvar::new(),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// Try to admit `psums` of work under `policy`.
    pub fn admit(&self, psums: u64, policy: Policy) -> Admission {
        let mut inflight = self.inflight.lock().expect("admission lock");
        loop {
            // A single oversized job is admitted when idle rather than
            // deadlocking forever.
            let fits = *inflight + psums <= self.max_inflight_psums
                || (*inflight == 0 && psums > self.max_inflight_psums);
            if fits {
                *inflight += psums;
                self.admitted.fetch_add(1, Ordering::Relaxed);
                return Admission::Admitted;
            }
            match policy {
                Policy::Reject => {
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    return Admission::Rejected;
                }
                Policy::Block => {
                    inflight = self.freed.wait(inflight).expect("admission wait");
                }
            }
        }
    }

    /// Mark `psums` of admitted work complete.
    pub fn complete(&self, psums: u64) {
        let mut inflight = self.inflight.lock().expect("admission lock");
        *inflight = inflight.saturating_sub(psums);
        drop(inflight);
        self.freed.notify_all();
    }

    pub fn inflight(&self) -> u64 {
        *self.inflight.lock().expect("admission lock")
    }

    pub fn capacity(&self) -> u64 {
        self.max_inflight_psums
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn admits_within_budget() {
        let ac = AdmissionController::new(100);
        assert_eq!(ac.admit(60, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.admit(40, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.inflight(), 100);
    }

    #[test]
    fn rejects_over_budget() {
        let ac = AdmissionController::new(100);
        assert_eq!(ac.admit(80, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.admit(30, Policy::Reject), Admission::Rejected);
        assert_eq!(ac.rejected.load(Ordering::Relaxed), 1);
        ac.complete(80);
        assert_eq!(ac.admit(30, Policy::Reject), Admission::Admitted);
    }

    #[test]
    fn oversized_job_admitted_when_idle() {
        let ac = AdmissionController::new(10);
        assert_eq!(ac.admit(1000, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.admit(1, Policy::Reject), Admission::Rejected);
        ac.complete(1000);
        assert_eq!(ac.admit(1, Policy::Reject), Admission::Admitted);
    }

    #[test]
    fn block_policy_waits_for_completion() {
        let ac = Arc::new(AdmissionController::new(50));
        assert_eq!(ac.admit(50, Policy::Block), Admission::Admitted);
        let ac2 = Arc::clone(&ac);
        let waiter = std::thread::spawn(move || ac2.admit(20, Policy::Block));
        std::thread::sleep(Duration::from_millis(50));
        assert!(!waiter.is_finished(), "submitter must be blocked");
        ac.complete(50);
        assert_eq!(waiter.join().unwrap(), Admission::Admitted);
        assert_eq!(ac.inflight(), 20);
    }

    #[test]
    fn complete_never_underflows() {
        let ac = AdmissionController::new(10);
        ac.complete(99);
        assert_eq!(ac.inflight(), 0);
    }

    #[test]
    fn concurrent_admissions_respect_budget() {
        let ac = Arc::new(AdmissionController::new(100));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let ac = Arc::clone(&ac);
            handles.push(std::thread::spawn(move || {
                let mut admitted = 0;
                for _ in 0..50 {
                    if ac.admit(10, Policy::Reject) == Admission::Admitted {
                        admitted += 1;
                        std::thread::yield_now();
                        ac.complete(10);
                    }
                }
                admitted
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0);
        assert_eq!(ac.inflight(), 0);
    }
}
