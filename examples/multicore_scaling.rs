//! Experiment S52 scaling: the paper's 0.224 GOPS single-core and
//! 4.48 GOPS 20-core claims, measured end-to-end through the
//! coordinator's core pool (not just multiplied out).
//!
//! ```bash
//! cargo run --release --example multicore_scaling -- [--requests N]
//! ```
//!
//! Each core count serves the same S52-heavy trace; simulated GOPS is
//! computed from per-core cycle totals. Expect near-linear scaling —
//! cores are independent (separate BRAM sets), as in the paper.

use repro::coordinator::{CoordinatorConfig, Server};
use repro::model::trace::{generate, TraceConfig};
use repro::paper::{GOPS_20, GOPS_SINGLE, MAX_CORES_Z2};
use repro::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[]).map_err(|e| anyhow::anyhow!(e))?;
    let n = args.get_usize("requests", 40).map_err(|e| anyhow::anyhow!(e))?;

    let trace = generate(&TraceConfig {
        n,
        mean_gap_us: 0,
        s52_fraction: 1.0, // pure §5.2 workload
        seed: 52,
    });

    println!("S52 trace: {n} requests of 224x224x8 (x) 8x3x3x8\n");
    println!(
        "{:>5} {:>14} {:>12} {:>10} {:>12}",
        "cores", "sim GOPS", "vs paper", "host RPS", "p99 (us)"
    );
    let mut results = Vec::new();
    for cores in [1usize, 2, 4, 8, 16, MAX_CORES_Z2] {
        let mut server = Server::new(CoordinatorConfig::default().with_cores(cores));
        let report = server.run_trace(&trace);
        server.shutdown();
        let expected = GOPS_SINGLE * cores as f64;
        println!(
            "{:>5} {:>14.4} {:>11.1}% {:>10.1} {:>12}",
            cores,
            report.sim_gops_psum,
            report.sim_gops_psum / expected * 100.0,
            report.host_rps,
            report.p99_us
        );
        results.push((cores, report.sim_gops_psum));
    }

    let single = results[0].1;
    let twenty = results.last().unwrap().1;
    println!("\npaper: single core {GOPS_SINGLE} GOPS, 20 cores {GOPS_20} GOPS");
    println!("ours:  single core {single:.4} GOPS, 20 cores {twenty:.4} GOPS");
    let lin = twenty / (single * MAX_CORES_Z2 as f64);
    println!("scaling efficiency at 20 cores: {:.1}%", lin * 100.0);
    Ok(())
}
