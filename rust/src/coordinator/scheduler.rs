//! CNN layer scheduler: runs a whole network through one conv backend,
//! chaining layers the way §4.1 intends — each layer's output BMGs
//! become the next layer's input BMGs, so intermediate feature maps
//! never cross the DMA. Only the first image in and the final logits
//! out pay transfer cycles.
//!
//! The scheduler is generic over [`ConvBackend`]: the default is the
//! cycle-accurate simulated IP core, but the same chaining logic runs a
//! network on the golden CPU fallback or (when linked) the XLA path —
//! the per-layer numerics are bit-identical by the backend parity
//! contract, only the cost accounting differs.
//!
//! Between layers the scheduler applies the activation + requantisation
//! the PS owns in a real deployment (ReLU folds into the requant clamp;
//! see `model::quant`).

use crate::backend::{ConvBackend, JobKind, JobPayload, SimBackend};
use crate::hw::ip_core::CycleStats;
use crate::hw::IpCoreConfig;
use crate::model::network::EdgeCnn;
use crate::model::{golden, maxpool2x2, Tensor};

/// Per-layer record of a scheduled inference.
#[derive(Clone, Debug)]
pub struct LayerRecord {
    pub name: String,
    pub cycles: CycleStats,
    pub psums: u64,
}

/// Whole-inference result.
#[derive(Clone, Debug)]
pub struct InferenceRun {
    pub logits: Vec<i32>,
    pub class: usize,
    pub layers: Vec<LayerRecord>,
    /// Total simulated cycles including the boundary DMAs.
    pub total_cycles: u64,
    /// What the same inference would cost with a DMA round-trip per
    /// layer (the ablation §4.1's output-BRAM chaining avoids).
    pub total_cycles_dma_roundtrip: u64,
}

/// Scheduler owning one conv backend and one network's parameters.
pub struct CnnScheduler<B: ConvBackend = SimBackend> {
    pub backend: B,
    pub net: EdgeCnn,
}

impl CnnScheduler<SimBackend> {
    /// The paper's deployment: one simulated IP core.
    pub fn new(config: IpCoreConfig, net: EdgeCnn) -> Self {
        Self::with_backend(SimBackend::new(config), net)
    }
}

impl<B: ConvBackend> CnnScheduler<B> {
    /// Schedule onto any conv backend.
    pub fn with_backend(backend: B, net: EdgeCnn) -> Self {
        CnnScheduler { backend, net }
    }

    /// Run one image through the network on the backend.
    pub fn infer(&mut self, img: &Tensor<u8>) -> anyhow::Result<InferenceRun> {
        let n = self.net.params.layers.len();
        let mut x = img.clone();
        let mut layers = Vec::with_capacity(n);
        let mut total = 0u64;
        let mut total_roundtrip = 0u64;

        for i in 0..n {
            let lp = self.net.params.layers[i].clone();
            let run = self.backend.run(&JobPayload {
                kind: JobKind::Standard,
                spec: &lp.spec,
                img: &x,
                weights: &lp.weights,
                bias: &lp.bias,
                weights_resident: false,
                trace_id: 0,
            })?;
            let mut out = run.output;
            if lp.spec.relu {
                for v in out.data_mut() {
                    if *v < 0 {
                        *v = 0;
                    }
                }
            }
            if lp.spec.pool {
                out = maxpool2x2(&out);
            }

            // §4.1 chaining: inner boundaries skip DMA entirely; the
            // round-trip ablation pays both directions every layer.
            let compute_latency = run.cycles.compute + run.cycles.load_visible;
            let boundary_dma = match i {
                0 => run.cycles.dma_in,
                _ => 0,
            } + if i == n - 1 { run.cycles.dma_out } else { 0 };
            total += compute_latency + boundary_dma;
            total_roundtrip += compute_latency + run.cycles.dma_in + run.cycles.dma_out;

            layers.push(LayerRecord {
                name: lp.spec.name(),
                cycles: run.cycles,
                psums: lp.spec.psums(),
            });

            if i + 1 < n {
                x = self.net.params.requants[i].apply(&out);
            } else {
                let logits = out.into_data();
                let class = crate::model::network::argmax(&logits);
                return Ok(InferenceRun {
                    logits,
                    class,
                    layers,
                    total_cycles: total,
                    total_cycles_dma_roundtrip: total_roundtrip,
                });
            }
        }
        unreachable!("network has at least one layer")
    }

    /// Golden-path parity check: the scheduled (backend) logits must
    /// equal the pure-software reference.
    pub fn verify_against_golden(&mut self, img: &Tensor<u8>) -> anyhow::Result<bool> {
        let hw = self.infer(img)?;
        let sw = self.net.forward_golden(img);
        Ok(hw.logits == sw)
    }
}

/// Software-only reference timing: what the PS alone would do (naive
/// golden conv per layer) — used by benches for the speedup narrative.
pub fn golden_inference_logits(net: &EdgeCnn, img: &Tensor<u8>) -> Vec<i32> {
    net.forward_golden(img)
}

/// Convenience: golden conv as a closure target for benches.
pub fn golden_layer(
    spec: &crate::model::LayerSpec,
    img: &Tensor<u8>,
    w: &Tensor<u8>,
    bias: &[i32],
) -> Tensor<i32> {
    let mut out = golden::conv3x3_i32(img, w, bias, spec.relu);
    if spec.pool {
        out = maxpool2x2(&out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;

    #[test]
    fn scheduled_inference_matches_golden() {
        let net = EdgeCnn::new(11);
        let img = EdgeCnn::sample_input(3, &net.specs()[0]);
        let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
        assert!(sched.verify_against_golden(&img).unwrap());
    }

    #[test]
    fn chaining_beats_dma_roundtrip() {
        let net = EdgeCnn::new(12);
        let img = EdgeCnn::sample_input(4, &net.specs()[0]);
        let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
        let run = sched.infer(&img).unwrap();
        assert!(run.total_cycles < run.total_cycles_dma_roundtrip);
        assert_eq!(run.layers.len(), 5);
    }

    #[test]
    fn per_layer_records_are_complete() {
        let net = EdgeCnn::new(13);
        let img = EdgeCnn::sample_input(5, &net.specs()[0]);
        let specs = net.specs();
        let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
        let run = sched.infer(&img).unwrap();
        for (rec, spec) in run.layers.iter().zip(&specs) {
            assert_eq!(rec.name, spec.name());
            assert_eq!(rec.psums, spec.psums());
            assert!(rec.cycles.compute > 0);
        }
        assert!(run.class < 32);
    }

    #[test]
    fn repeated_inference_is_deterministic() {
        let net = EdgeCnn::new(14);
        let img = EdgeCnn::sample_input(6, &net.specs()[0]);
        let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
        let a = sched.infer(&img).unwrap();
        let b = sched.infer(&img).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total_cycles, b.total_cycles);
    }

    #[test]
    fn generic_scheduler_runs_on_the_golden_backend() {
        // Same chaining logic, different backend: logits must agree
        // with both the golden reference and the simulated-core path.
        let img = EdgeCnn::sample_input(9, &EdgeCnn::new(15).specs()[0]);
        let mut on_golden = CnnScheduler::with_backend(GoldenBackend::new(), EdgeCnn::new(15));
        let mut on_sim = CnnScheduler::new(IpCoreConfig::default(), EdgeCnn::new(15));
        let a = on_golden.infer(&img).unwrap();
        let b = on_sim.infer(&img).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.class, b.class);
        // Host backend models no DMA, so chaining saves nothing there.
        assert_eq!(a.total_cycles, a.total_cycles_dma_roundtrip);
        assert!(b.total_cycles < b.total_cycles_dma_roundtrip);
    }

    #[test]
    fn generic_scheduler_runs_on_the_im2col_backend() {
        // The threaded host kernel under the same chaining logic:
        // logits bit-identical to the simulated core's.
        use crate::backend::Im2colBackend;
        let img = EdgeCnn::sample_input(10, &EdgeCnn::new(16).specs()[0]);
        let mut on_im2col = CnnScheduler::with_backend(Im2colBackend::new(4), EdgeCnn::new(16));
        let mut on_sim = CnnScheduler::new(IpCoreConfig::default(), EdgeCnn::new(16));
        let a = on_im2col.infer(&img).unwrap();
        let b = on_sim.infer(&img).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.class, b.class);
        assert!(on_im2col.verify_against_golden(&img).unwrap());
    }
}
