//! Experiment F6 (DESIGN.md §4): bit-exact reproduction of the paper's
//! Fig. 6 simulation waveform — one computing core, four kernels, the
//! 5-wide ramp feature, 8-bit wrapping PSUMs.

use repro::hw::waveform::{fig6_stimulus, WaveTrace, FIG6_PSUMS};
use repro::hw::{AccumMode, IpCore, IpCoreConfig};
use repro::model::golden;

fn traced_run() -> WaveTrace {
    let (spec, img, weights, bias) = fig6_stimulus();
    let mut trace = WaveTrace::fig6();
    let mut core = IpCore::new(IpCoreConfig {
        mode: AccumMode::Wrap8,
        ..Default::default()
    });
    core.run_layer(&spec, &img, &weights, &bias, Some(&mut trace))
        .expect("fig6 layer runs");
    trace
}

#[test]
fn psum_sequences_match_figure_bit_exactly() {
    let trace = traced_run();
    for (j, expected) in FIG6_PSUMS.iter().enumerate() {
        let series = trace
            .series(&format!("psum_{j}"))
            .expect("psum signal traced");
        assert_eq!(series.len(), 9, "3x3 windows over a 5x5 feature");
        let got: Vec<u8> = series
            .iter()
            .map(|s| u8::from_str_radix(s, 16).unwrap())
            .collect();
        assert_eq!(&got[..], expected, "psum_{j} full sequence");
    }
}

#[test]
fn weight_signals_match_figure() {
    let trace = traced_run();
    let expected = [
        "010203040506070809",
        "919293949596979899",
        "212223242526272829",
        "b1b2b3b4b5b6b7b8b9",
    ];
    for (j, want) in expected.iter().enumerate() {
        let series = trace.series(&format!("weight{j}")).unwrap();
        assert!(series.iter().all(|v| v == want), "weight{j} stationary");
    }
}

#[test]
fn feature_signals_slide_as_in_figure() {
    let trace = traced_run();
    // First three window columns of feature0, straight off the figure.
    let f0 = trace.series("feature0").unwrap();
    assert_eq!(&f0[..4], &["010203", "020304", "030405", "060708"]);
    let f1 = trace.series("feature1").unwrap();
    assert_eq!(&f1[..4], &["060708", "070809", "08090a", "0b0c0d"]);
    let f2 = trace.series("feature2").unwrap();
    assert_eq!(&f2[..4], &["0b0c0d", "0c0d0e", "0d0e0f", "101112"]);
}

#[test]
fn eight_cycles_per_psum_group() {
    let trace = traced_run();
    let cycles: Vec<u64> = trace.rows.iter().map(|(c, _)| *c).collect();
    assert_eq!(cycles, (1..=9).map(|i| i * 8).collect::<Vec<_>>());
}

#[test]
fn figure_values_equal_wrap8_golden() {
    // Cross-check: the traced PSUMs are exactly the wrap-8 golden conv.
    let (_, img, weights, _) = fig6_stimulus();
    let out = golden::conv3x3_wrap8(&img, &weights, &[0; 4]);
    for (j, expected) in FIG6_PSUMS.iter().enumerate() {
        let row: Vec<u8> = (0..3)
            .flat_map(|y| (0..3).map(move |x| (y, x)))
            .map(|(y, x)| out.at3(j, y, x))
            .collect();
        assert_eq!(&row[..], expected);
    }
}

#[test]
fn vcd_export_round_trips_header() {
    let trace = traced_run();
    let vcd = trace.to_vcd(9);
    assert!(vcd.contains("$var wire 72"));
    assert!(vcd.contains("$var wire 8"));
    assert!(vcd.contains("#72"), "last window at cycle 72");
}
