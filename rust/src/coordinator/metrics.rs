//! Coordinator metrics: counters, simulated-cycle roll-up and a
//! log-bucketed latency histogram (std-only, lock-free counters).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log2-bucketed latency histogram, 1 µs .. ~1 s.
#[derive(Debug)]
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i µs, 2^(i+1) µs).
    buckets: Vec<AtomicU64>,
}

const N_BUCKETS: usize = 21; // 2^20 µs ≈ 1 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(N_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Upper bound (µs) of the bucket containing quantile `q` (0..1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << N_BUCKETS
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    /// Jobs a backend failed (answered with an error result) — e.g. a
    /// dropped remote peer. Not counted in `completed`. With failover
    /// this counts *terminal* failures only: a job that fails on one
    /// worker and succeeds on a sibling counts in `retried` and
    /// `completed`, not here.
    pub failed: AtomicU64,
    /// Failover hops: a worker failed a job and the pool re-enqueued it
    /// on a capable sibling. One job can contribute several hops.
    pub retried: AtomicU64,
    /// Requests refused up front by admission control (the client got a
    /// fast `rejected` answer instead of queueing).
    pub shed: AtomicU64,
    pub psums: AtomicU64,
    pub sim_cycles: AtomicU64,
    pub weight_dma_skipped: AtomicU64,
    /// Wire-v4 weight-store hits: hash-only requests served from the
    /// content-addressed store without the blob crossing the wire.
    pub weight_hits: AtomicU64,
    /// Wire-v4 weight-store misses: hash-only requests answered with a
    /// `need_weights` frame (client must re-send the blob inline once).
    pub weight_misses: AtomicU64,
    /// Weight bytes that did *not* cross the wire thanks to store hits.
    pub weight_bytes_saved: AtomicU64,
    /// Weight bytes that *did* arrive inline over the wire (v2/v3 JSON
    /// arrays and v3/v4 binary bodies alike) — the ships-at-most-once
    /// property is asserted against this counter.
    pub wire_weight_bytes: AtomicU64,
    pub latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_completion(&self, psums: u64, cycles: u64, latency: Duration, reused: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.psums.fetch_add(psums, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        if reused {
            self.weight_dma_skipped.fetch_add(1, Ordering::Relaxed);
        }
        self.latency.record(latency);
    }

    /// Record a job a backend failed terminally (the pool answered it
    /// with an error result instead of numerics).
    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one failover hop (job re-enqueued on a sibling worker).
    pub fn record_retry(&self) {
        self.retried.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request shed by admission control.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a weight-store hit that kept `bytes` weight bytes off the
    /// wire.
    pub fn record_weight_hit(&self, bytes: u64) {
        self.weight_hits.fetch_add(1, Ordering::Relaxed);
        self.weight_bytes_saved.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record a weight-store miss (a `need_weights` frame went out).
    pub fn record_weight_miss(&self) {
        self.weight_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `bytes` of inline weight payload received over the wire.
    pub fn record_wire_weight_bytes(&self, bytes: u64) {
        self.wire_weight_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Simulated GOPS in the paper's PSUM accounting, given the board
    /// frequency and the number of parallel cores that produced the
    /// cycles (per-core cycles accumulate into `sim_cycles`).
    pub fn sim_gops_psum(&self, freq_hz: u64, n_cores: usize) -> f64 {
        let cycles = self.sim_cycles.load(Ordering::Relaxed);
        if cycles == 0 {
            return 0.0;
        }
        // Wall time = per-core cycles; with even load, per-core ≈ total/n.
        let wall_cycles = cycles as f64 / n_cores as f64;
        self.psums.load(Ordering::Relaxed) as f64 / (wall_cycles / freq_hz as f64) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record(Duration::from_micros(10));
        }
        h.record(Duration::from_millis(100));
        assert_eq!(h.count(), 100);
        assert!(h.quantile_us(0.5) <= 16);
        assert!(h.quantile_us(1.0) >= 100_000 / 2);
    }

    #[test]
    fn zero_latency_lands_in_first_bucket() {
        let h = LatencyHistogram::new();
        h.record(Duration::ZERO);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_us(1.0) <= 2);
    }

    #[test]
    fn weight_cache_counters_accumulate_independently() {
        let m = Metrics::new();
        m.record_weight_hit(2304);
        m.record_weight_hit(2304);
        m.record_weight_miss();
        m.record_wire_weight_bytes(2304);
        assert_eq!(m.weight_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.weight_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.weight_bytes_saved.load(Ordering::Relaxed), 4608);
        assert_eq!(m.wire_weight_bytes.load(Ordering::Relaxed), 2304);
        // Orthogonal to the completion counters.
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn gops_accounting_single_core() {
        let m = Metrics::new();
        // 2 psums per cycle at 112 MHz -> 0.224 GOPS (paper).
        m.record_completion(2 * 1000, 1000, Duration::from_micros(5), false);
        let gops = m.sim_gops_psum(112_000_000, 1);
        assert!((gops - 0.224).abs() < 1e-9, "{gops}");
    }

    #[test]
    fn gops_scales_with_cores() {
        let m = Metrics::new();
        // Two cores each did 1000 cycles of 2-psum/cycle work.
        m.record_completion(2000, 1000, Duration::from_micros(5), false);
        m.record_completion(2000, 1000, Duration::from_micros(5), false);
        let one = m.sim_gops_psum(112_000_000, 1);
        let two = m.sim_gops_psum(112_000_000, 2);
        assert!((two / one - 2.0).abs() < 1e-9);
    }
}
