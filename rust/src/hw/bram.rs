//! BMG (Block Memory Generator) models and the §4.1 BRAM organisation.
//!
//! A [`Bmg`] is a dual-port RAM: two concurrent accesses per cycle, which
//! is exactly why the architecture spreads data over *multiple* BMGs —
//! four image BMGs (one per channel quarter), 4×4 weight BMGs (channel
//! quarter × interleaved kernel quarter) and four output BMGs (output
//! channel quarter, kernel `k` lives in BMG `k % 4` so the four PSUMs of
//! one kernel group land in four different BMGs and never fight for a
//! port).

use crate::model::Tensor;
use crate::paper::{KH, KW, N_CORES, N_PCORES};

/// Dual-port block RAM of `DEPTH` words of `T`.
///
/// The model tracks port activity per cycle so the simulator can assert
/// the §4.1 claim that the BMG split makes all concurrent accesses
/// conflict-free (2 ports per BMG are never exceeded).
#[derive(Clone, Debug)]
pub struct Bmg<T> {
    name: String,
    data: Vec<T>,
    /// Highest address ever touched (utilisation reporting: §4.1 notes
    /// small images leave "redundant slots"). `None` until first access.
    high_water: Option<usize>,
    /// Total reads/writes (for bandwidth accounting).
    pub reads: u64,
    pub writes: u64,
}

impl<T: Copy + Default> Bmg<T> {
    pub fn new(name: impl Into<String>, depth: usize) -> Self {
        Bmg {
            name: name.into(),
            data: vec![T::default(); depth],
            high_water: None,
            reads: 0,
            writes: 0,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn depth(&self) -> usize {
        self.data.len()
    }

    /// Fraction of the BMG ever used — §4.1's "redundant slots" metric.
    pub fn utilisation(&self) -> f64 {
        match (self.high_water, self.data.len()) {
            (None, _) | (_, 0) => 0.0,
            (Some(hw), len) => (hw + 1).min(len) as f64 / len as f64,
        }
    }

    #[inline]
    fn touch(&mut self, addr: usize) {
        self.high_water = Some(self.high_water.map_or(addr, |h| h.max(addr)));
    }

    #[inline]
    pub fn read(&mut self, addr: usize) -> T {
        self.reads += 1;
        self.touch(addr);
        self.data[addr]
    }

    #[inline]
    pub fn write(&mut self, addr: usize, v: T) {
        self.writes += 1;
        self.touch(addr);
        self.data[addr] = v;
    }

    /// Peek without counting a port access (testbench/DMA-view only).
    #[inline]
    pub fn peek(&self, addr: usize) -> T {
        self.data[addr]
    }

    /// Fast-path bulk read: borrow `[start, start+len)` directly while
    /// charging `reads` port accesses in one update. Semantically a
    /// sequence of `read()` calls — the §Perf pass uses this to keep the
    /// per-byte model out of the simulator's hot loop without losing
    /// the port accounting (EXPERIMENTS.md §Perf).
    #[inline]
    pub fn read_bulk(&mut self, start: usize, len: usize, reads: u64) -> &[T] {
        self.reads += reads;
        if len > 0 {
            self.touch(start + len - 1);
        }
        &self.data[start..start + len]
    }
}

impl<T: AccumWord> Bmg<T> {
    /// Fast-path bulk read-modify-write: `data[start+i] += vals[i]`,
    /// charging one read + one write per element.
    #[inline]
    pub fn accum_bulk(&mut self, start: usize, vals: &[T]) {
        self.reads += vals.len() as u64;
        self.writes += vals.len() as u64;
        if !vals.is_empty() {
            self.touch(start + vals.len() - 1);
        }
        for (slot, v) in self.data[start..start + vals.len()].iter_mut().zip(vals) {
            *slot = slot.accum(*v);
        }
    }
}

// ---------------------------------------------------------------------------
// §4.1 Input BRAMs: 4 BMGs, each one-fourth of the image channels.
// ---------------------------------------------------------------------------

/// The set of four image BMGs. BMG `i` stores channels
/// `[i*C/4, (i+1)*C/4)` (contiguous quarters, so each computing core
/// reads only its own BMG). When `C` is not divisible by 4 (the
/// first-layer exception the paper notes) channels are distributed
/// round-robin-by-quarter with the remainder in the low quarters.
#[derive(Clone, Debug)]
pub struct ImageBrams {
    pub banks: Vec<Bmg<u8>>,
    c: usize,
    h: usize,
    w: usize,
}

/// How many channels quarter `q` owns for `c` total channels.
pub fn quarter_span(c: usize, q: usize) -> (usize, usize) {
    // Contiguous split with remainder spread over the first quarters.
    let base = c / N_CORES;
    let rem = c % N_CORES;
    let start = q * base + q.min(rem);
    let len = base + usize::from(q < rem);
    (start, len)
}

impl ImageBrams {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        let banks = (0..N_CORES)
            .map(|q| {
                let (_, len) = quarter_span(c, q);
                Bmg::new(format!("img_bmg{q}"), len.max(1) * h * w)
            })
            .collect();
        ImageBrams { banks, c, h, w }
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }

    /// (bank, address) for channel `c`, row `y`, col `x`.
    #[inline]
    pub fn locate(&self, ch: usize, y: usize, x: usize) -> (usize, usize) {
        let (bank, local) = self.bank_of(ch);
        (bank, (local * self.h + y) * self.w + x)
    }

    #[inline]
    fn bank_of(&self, ch: usize) -> (usize, usize) {
        for q in 0..N_CORES {
            let (start, len) = quarter_span(self.c, q);
            if ch >= start && ch < start + len {
                return (q, ch - start);
            }
        }
        unreachable!("channel {ch} out of range {}", self.c)
    }

    /// DMA-side bulk load of a whole (C,H,W) image.
    pub fn load_image(&mut self, img: &Tensor<u8>) {
        assert_eq!(img.shape(), &[self.c, self.h, self.w]);
        for ch in 0..self.c {
            for y in 0..self.h {
                for x in 0..self.w {
                    let (b, a) = self.locate(ch, y, x);
                    self.banks[b].write(a, img.at3(ch, y, x));
                }
            }
        }
    }

    /// Core-side read.
    #[inline]
    pub fn read(&mut self, ch: usize, y: usize, x: usize) -> u8 {
        let (b, a) = self.locate(ch, y, x);
        self.banks[b].read(a)
    }

    /// Fast path: borrow channel `ch`'s whole H×W plane, charging
    /// `reads` port accesses in bulk (the loader's closed-form count).
    #[inline]
    pub fn plane_bulk(&mut self, ch: usize, reads: u64) -> &[u8] {
        let (b, base) = self.locate(ch, 0, 0);
        let len = self.h * self.w;
        self.banks[b].read_bulk(base, len, reads)
    }
}

// ---------------------------------------------------------------------------
// §4.1 Weight BRAMs: 4 groups x 4 BMGs (channel quarter x kernel quarter).
// ---------------------------------------------------------------------------

/// Weight BMG grid. BMG `(q, j)` holds, for channels of quarter `q`, the
/// weights of kernels `k` with `k % 4 == j` — the interleaved kernel
/// split that lets one kernel *group* (4 consecutive kernels) stream
/// from 4 distinct BMGs at once.
#[derive(Clone, Debug)]
pub struct WeightBrams {
    pub banks: Vec<Vec<Bmg<u8>>>, // [channel quarter][kernel quarter]
    k: usize,
    c: usize,
}

impl WeightBrams {
    pub fn new(k: usize, c: usize) -> Self {
        assert!(k % N_PCORES == 0, "paper §4.1: kernel count divisible by 4");
        let banks = (0..N_CORES)
            .map(|q| {
                let (_, clen) = quarter_span(c, q);
                (0..N_PCORES)
                    .map(|j| {
                        Bmg::new(
                            format!("wgt_bmg{q}_{j}"),
                            (k / N_PCORES) * clen.max(1) * KH * KW,
                        )
                    })
                    .collect()
            })
            .collect();
        WeightBrams { banks, k, c }
    }

    /// (channel-quarter bank, kernel bank, address) for weight
    /// `W[k][ch][dy][dx]`.
    #[inline]
    pub fn locate(&self, k: usize, ch: usize, dy: usize, dx: usize) -> (usize, usize, usize) {
        let j = k % N_PCORES;
        let kslot = k / N_PCORES;
        let (q, local) = self.bank_of_channel(ch);
        let addr = ((kslot * self.quarter_len(q) + local) * KH + dy) * KW + dx;
        (q, j, addr)
    }

    fn quarter_len(&self, q: usize) -> usize {
        quarter_span(self.c, q).1.max(1)
    }

    fn bank_of_channel(&self, ch: usize) -> (usize, usize) {
        for q in 0..N_CORES {
            let (start, len) = quarter_span(self.c, q);
            if ch >= start && ch < start + len {
                return (q, ch - start);
            }
        }
        unreachable!("channel {ch} out of range {}", self.c)
    }

    /// DMA-side bulk load of a whole (K,C,3,3) weight tensor.
    pub fn load_weights(&mut self, w: &Tensor<u8>) {
        assert_eq!(w.shape(), &[self.k, self.c, KH, KW]);
        for k in 0..self.k {
            for ch in 0..self.c {
                for dy in 0..KH {
                    for dx in 0..KW {
                        let (q, j, a) = self.locate(k, ch, dy, dx);
                        self.banks[q][j].write(a, w.at4(k, ch, dy, dx));
                    }
                }
            }
        }
    }

    /// Core-side read of one 9-weight channel slice of kernel `k`.
    pub fn read_kernel_channel(&mut self, k: usize, ch: usize) -> [u8; 9] {
        let mut out = [0u8; 9];
        for dy in 0..KH {
            for dx in 0..KW {
                let (q, j, a) = self.locate(k, ch, dy, dx);
                out[dy * KW + dx] = self.banks[q][j].read(a);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// §4.1 Output BRAMs: 4 BMGs by output-channel (kernel) quarter,
// interleaved (k % 4), with an accumulating write port.
// ---------------------------------------------------------------------------

/// Output BMG set, generic over the accumulator word ([`u8`] for Wrap8,
/// [`i32`] for production). Kernel `k`'s feature map lives in BMG
/// `k % 4`; the "accumulate" op models the read-modify-write the paper
/// uses to fold PSUMs (and the pre-loaded bias) together in BRAM.
#[derive(Clone, Debug)]
pub struct OutputBrams<T> {
    pub banks: Vec<Bmg<T>>,
    k: usize,
    oh: usize,
    ow: usize,
}

pub trait AccumWord: Copy + Default {
    fn accum(self, rhs: Self) -> Self;
}

impl AccumWord for u8 {
    #[inline]
    fn accum(self, rhs: u8) -> u8 {
        self.wrapping_add(rhs)
    }
}

impl AccumWord for i32 {
    #[inline]
    fn accum(self, rhs: i32) -> i32 {
        self + rhs
    }
}

impl<T: AccumWord> OutputBrams<T> {
    pub fn new(k: usize, oh: usize, ow: usize) -> Self {
        let per_bank = k.div_ceil(N_PCORES);
        let banks = (0..N_PCORES)
            .map(|j| Bmg::new(format!("out_bmg{j}"), per_bank.max(1) * oh * ow))
            .collect();
        OutputBrams { banks, k, oh, ow }
    }

    #[inline]
    pub fn locate(&self, k: usize, y: usize, x: usize) -> (usize, usize) {
        let j = k % N_PCORES;
        let slot = k / N_PCORES;
        (j, (slot * self.oh + y) * self.ow + x)
    }

    /// The PS-side bias preload (§4.2 "Bias Handling").
    pub fn preload_bias(&mut self, bias: &[T]) {
        assert_eq!(bias.len(), self.k);
        for k in 0..self.k {
            for y in 0..self.oh {
                for x in 0..self.ow {
                    let (j, a) = self.locate(k, y, x);
                    self.banks[j].write(a, bias[k]);
                }
            }
        }
    }

    /// Accumulating write: `mem[k,y,x] += v` (one read + one write port).
    #[inline]
    pub fn accumulate(&mut self, k: usize, y: usize, x: usize, v: T) {
        let (j, a) = self.locate(k, y, x);
        let cur = self.banks[j].read(a);
        self.banks[j].write(a, cur.accum(v));
    }

    /// Fast path: accumulate one whole output row of kernel `k`
    /// (`vals.len() == OW`), identical semantics/port counts to `OW`
    /// calls of [`Self::accumulate`].
    #[inline]
    pub fn accumulate_row(&mut self, k: usize, y: usize, vals: &[T]) {
        let (j, base) = self.locate(k, y, 0);
        self.banks[j].accum_bulk(base, vals);
    }

    /// DMA-side readout into a tensor.
    pub fn readout(&mut self) -> Tensor<T> {
        let mut out = Tensor::<T>::zeros(&[self.k, self.oh, self.ow]);
        for k in 0..self.k {
            for y in 0..self.oh {
                for x in 0..self.ow {
                    let (j, a) = self.locate(k, y, x);
                    let v = self.banks[j].read(a);
                    out.set3(k, y, x, v);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn quarter_span_partitions() {
        for c in [1, 2, 3, 4, 5, 8, 9, 16, 64] {
            let mut covered = 0;
            let mut next = 0;
            for q in 0..N_CORES {
                let (start, len) = quarter_span(c, q);
                assert_eq!(start, next, "quarters contiguous for c={c}");
                next += len;
                covered += len;
            }
            assert_eq!(covered, c, "quarters partition c={c}");
        }
    }

    #[test]
    fn divisible_by_4_gives_equal_quarters() {
        for q in 0..4 {
            assert_eq!(quarter_span(8, q).1, 2);
            assert_eq!(quarter_span(16, q).1, 4);
        }
    }

    #[test]
    fn image_round_trip() {
        let mut rng = Prng::new(1);
        let img = Tensor::from_vec(&[8, 5, 6], rng.bytes_below(8 * 5 * 6, 256));
        let mut brams = ImageBrams::new(8, 5, 6);
        brams.load_image(&img);
        for ch in 0..8 {
            for y in 0..5 {
                for x in 0..6 {
                    assert_eq!(brams.read(ch, y, x), img.at3(ch, y, x));
                }
            }
        }
    }

    #[test]
    fn image_channels_land_in_their_quarter_bank() {
        let mut brams = ImageBrams::new(8, 4, 4);
        // channel 0,1 -> bank 0; 2,3 -> bank 1; etc.
        assert_eq!(brams.locate(0, 0, 0).0, 0);
        assert_eq!(brams.locate(1, 0, 0).0, 0);
        assert_eq!(brams.locate(2, 0, 0).0, 1);
        assert_eq!(brams.locate(7, 3, 3).0, 3);
        let _ = &mut brams; // silence unused-mut lint paths
    }

    #[test]
    fn weight_round_trip_and_kernel_interleave() {
        let mut rng = Prng::new(2);
        let w = Tensor::from_vec(&[8, 8, 3, 3], rng.bytes_below(8 * 8 * 9, 256));
        let mut brams = WeightBrams::new(8, 8);
        brams.load_weights(&w);
        for k in 0..8 {
            for ch in 0..8 {
                let got = brams.read_kernel_channel(k, ch);
                for dy in 0..3 {
                    for dx in 0..3 {
                        assert_eq!(got[dy * 3 + dx], w.at4(k, ch, dy, dx));
                    }
                }
                // interleaved: kernel k lives in kernel-bank k % 4
                assert_eq!(brams.locate(k, ch, 0, 0).1, k % 4);
            }
        }
    }

    #[test]
    fn kernel_group_streams_from_four_distinct_banks() {
        let brams = WeightBrams::new(8, 8);
        // group 1 = kernels 4..8 -> banks {0,1,2,3}
        let banks: Vec<usize> = (4..8).map(|k| brams.locate(k, 0, 0, 0).1).collect();
        let mut sorted = banks.clone();
        sorted.sort();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn output_accumulate_and_bias() {
        let mut out = OutputBrams::<i32>::new(4, 2, 2);
        out.preload_bias(&[10, 20, 30, 40]);
        out.accumulate(2, 1, 1, 5);
        out.accumulate(2, 1, 1, 7);
        let t = out.readout();
        assert_eq!(t.at3(2, 1, 1), 42);
        assert_eq!(t.at3(0, 0, 0), 10);
    }

    #[test]
    fn output_wrap8_accumulates_mod_256() {
        let mut out = OutputBrams::<u8>::new(4, 1, 1);
        out.preload_bias(&[250, 0, 0, 0]);
        out.accumulate(0, 0, 0, 10);
        assert_eq!(out.readout().at3(0, 0, 0), 4);
    }

    #[test]
    fn bmg_utilisation_tracks_high_water() {
        let mut b = Bmg::<u8>::new("t", 100);
        assert_eq!(b.utilisation(), 0.0);
        b.write(49, 1);
        assert!((b.utilisation() - 0.5).abs() < 1e-9);
        assert_eq!(b.reads, 0);
        assert_eq!(b.writes, 1);
    }
}
