//! Intermediate loaders (§4.2): the stage-1 side of the two-stage
//! pipeline. The **Weight Loader** fills the four PCOREs' register files
//! from the weight BMGs (once per kernel-group × channel — weight
//! stationary); the **Image Loader** fetches 3×3 windows from the image
//! BMG and broadcasts them to all four PCOREs, reusing the overlapping
//! two columns when the window slides by one.
//!
//! The loaders also own the *load-cycle accounting* that the pipeline
//! model needs: a dual-port BMG serves 2 reads per cycle, so a fresh
//! 9-value window costs ⌈9/2⌉ = 5 cycles and a slide costs ⌈3/2⌉ = 2.

use super::bram::{ImageBrams, WeightBrams};

/// Cycles to fetch through one dual-port BMG.
#[inline]
pub fn fetch_cycles(values: u64) -> u64 {
    values.div_ceil(2)
}

/// Image Loader: window register + slide-reuse fetch.
#[derive(Clone, Debug, Default)]
pub struct ImageLoader {
    window: [u8; 9],
    /// (channel, row, col) of the current window, if any.
    pos: Option<(usize, usize, usize)>,
    /// Load cycles spent (stage-1 time, to be overlapped by pipeline).
    pub load_cycles: u64,
    /// Values actually fetched from BRAM (reuse metric).
    pub fetched: u64,
}

impl ImageLoader {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn window(&self) -> [u8; 9] {
        self.window
    }

    /// Position the window at (channel, y, x), fetching only what the
    /// slide-by-one reuse cannot supply.
    pub fn fetch(&mut self, brams: &mut ImageBrams, ch: usize, y: usize, x: usize) -> [u8; 9] {
        let contiguous = matches!(self.pos, Some((c0, y0, x0)) if c0 == ch && y0 == y && x == x0 + 1);
        if contiguous {
            // Slide right: shift columns left, fetch the new right column.
            for r in 0..3 {
                self.window[r * 3] = self.window[r * 3 + 1];
                self.window[r * 3 + 1] = self.window[r * 3 + 2];
                self.window[r * 3 + 2] = brams.read(ch, y + r, x + 2);
            }
            self.fetched += 3;
            self.load_cycles += fetch_cycles(3);
        } else {
            for r in 0..3 {
                for c in 0..3 {
                    self.window[r * 3 + c] = brams.read(ch, y + r, x + c);
                }
            }
            self.fetched += 9;
            self.load_cycles += fetch_cycles(9);
        }
        self.pos = Some((ch, y, x));
        self.window
    }

    /// Fast-path bulk accounting: charge the closed-form fetch totals of
    /// a whole (group, channel) sweep in one update (what the per-window
    /// `fetch` loop would have accumulated: per output row one fresh
    /// window, `ow-1` slides). Resets window position — a subsequent
    /// traced fetch starts fresh.
    pub fn add_sweep_bulk(&mut self, oh: usize, ow: usize) -> (u64, u64) {
        let fetched = (oh * (9 + (ow - 1) * 3)) as u64;
        let cycles = (oh) as u64 * (fetch_cycles(9) + (ow as u64 - 1) * fetch_cycles(3));
        self.fetched += fetched;
        self.load_cycles += cycles;
        self.pos = None;
        (fetched, cycles)
    }
}

/// Weight Loader: stages one kernel-group × channel (4 × 9 weights) from
/// the four interleaved kernel BMGs in parallel.
#[derive(Clone, Debug, Default)]
pub struct WeightLoader {
    current: [[u8; 9]; 4],
    pub load_cycles: u64,
    pub loads: u64,
}

impl WeightLoader {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the 4 kernels of `group` (kernels `4*group + j`) at channel
    /// `ch`. The four kernel BMGs stream in parallel, so the cost is one
    /// BMG's 9 values, not 36.
    pub fn fetch_group(
        &mut self,
        brams: &mut WeightBrams,
        group: usize,
        ch: usize,
    ) -> [[u8; 9]; 4] {
        for j in 0..4 {
            self.current[j] = brams.read_kernel_channel(4 * group + j, ch);
        }
        self.loads += 1;
        self.load_cycles += fetch_cycles(9);
        self.current
    }

    pub fn current(&self) -> [[u8; 9]; 4] {
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tensor;
    use crate::util::prng::Prng;

    fn image(c: usize, h: usize, w: usize, seed: u64) -> (Tensor<u8>, ImageBrams) {
        let mut rng = Prng::new(seed);
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let mut brams = ImageBrams::new(c, h, w);
        brams.load_image(&img);
        (img, brams)
    }

    #[test]
    fn fetch_cycle_costs() {
        assert_eq!(fetch_cycles(9), 5);
        assert_eq!(fetch_cycles(3), 2);
        assert_eq!(fetch_cycles(0), 0);
    }

    #[test]
    fn window_contents_match_image() {
        let (img, mut brams) = image(4, 6, 6, 3);
        let mut ld = ImageLoader::new();
        let win = ld.fetch(&mut brams, 2, 1, 2);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(win[r * 3 + c], img.at3(2, 1 + r, 2 + c));
            }
        }
    }

    #[test]
    fn slide_reuses_two_columns() {
        let (img, mut brams) = image(1, 5, 8, 4);
        let mut ld = ImageLoader::new();
        ld.fetch(&mut brams, 0, 1, 0);
        let before = ld.fetched;
        let win = ld.fetch(&mut brams, 0, 1, 1); // slide right by one
        assert_eq!(ld.fetched - before, 3, "only the new column is fetched");
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(win[r * 3 + c], img.at3(0, 1 + r, 1 + c));
            }
        }
    }

    #[test]
    fn row_change_is_a_full_fetch() {
        let (_, mut brams) = image(1, 6, 6, 5);
        let mut ld = ImageLoader::new();
        ld.fetch(&mut brams, 0, 0, 3);
        let before = ld.fetched;
        ld.fetch(&mut brams, 0, 1, 0);
        assert_eq!(ld.fetched - before, 9);
    }

    #[test]
    fn weight_loader_stages_a_group() {
        let mut rng = Prng::new(6);
        let w = Tensor::from_vec(&[8, 4, 3, 3], rng.bytes_below(8 * 4 * 9, 256));
        let mut brams = WeightBrams::new(8, 4);
        brams.load_weights(&w);
        let mut wl = WeightLoader::new();
        let got = wl.fetch_group(&mut brams, 1, 2); // kernels 4..8, channel 2
        for j in 0..4 {
            for dy in 0..3 {
                for dx in 0..3 {
                    assert_eq!(got[j][dy * 3 + dx], w.at4(4 + j, 2, dy, dx));
                }
            }
        }
        assert_eq!(wl.load_cycles, fetch_cycles(9));
    }
}
