//! [`ConvBackend`] over the naive CPU reference convolutions.
//!
//! The honest host-fallback worker: a deployment keeps a few CPU
//! workers behind the accelerator pool so overflow traffic degrades in
//! latency instead of being shed. Outputs are bit-identical to the
//! simulated core (the golden functions *are* the anchor the simulator
//! is tested against); the reported cycles are the backend's own cost
//! model — modelled host-equivalent work, not simulated silicon.

use super::{BackendRun, Capability, ConvBackend, CostModel, JobKind, JobPayload};
use crate::hw::depthwise::golden_depthwise3x3;
use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::golden::conv3x3_i32;

/// Host-CPU reference backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct GoldenBackend;

impl GoldenBackend {
    pub fn new() -> Self {
        GoldenBackend
    }
}

impl ConvBackend for GoldenBackend {
    fn name(&self) -> &'static str {
        "golden-cpu"
    }

    fn capability(&self) -> Capability {
        Capability {
            standard3x3: true,
            depthwise: true,
            pointwise_as_3x3: true,
            accum: AccumMode::I32,
            paper_specs_only: false,
            spec_allowlist: None,
        }
    }

    fn cost_model(&self) -> CostModel {
        CostModel::HostMacs
    }

    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
        job.validate()?;
        let cost = self.cost(job.spec, job.kind);
        let output = match job.kind {
            JobKind::Standard | JobKind::PointwiseAs3x3 => {
                // Raw accumulator output, like the hardware path: the
                // serving layer owns activation + requant.
                conv3x3_i32(job.img, job.weights, job.bias, false)
            }
            JobKind::Depthwise => {
                golden_depthwise3x3(job.img, job.weights, job.bias, job.spec.relu)
            }
        };
        Ok(BackendRun {
            output,
            cycles: CycleStats {
                compute: cost,
                total: cost,
                ..Default::default()
            },
            wire: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::hw::IpCoreConfig;
    use crate::model::{LayerSpec, Tensor, QUICKSTART};
    use crate::util::prng::Prng;

    #[test]
    fn matches_sim_backend_bit_for_bit() {
        let spec = QUICKSTART;
        let mut rng = Prng::new(41);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        );
        let wts = Tensor::from_vec(
            &[spec.k, spec.c, 3, 3],
            rng.bytes_below(spec.k * spec.c * 9, 256),
        );
        let bias: Vec<i32> = (0..spec.k).map(|_| rng.range_i64(-9, 9) as i32).collect();
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let a = GoldenBackend::new().run(&payload).unwrap();
        let b = SimBackend::new(IpCoreConfig::default()).run(&payload).unwrap();
        assert_eq!(a.output.data(), b.output.data());
    }

    #[test]
    fn rejects_mismatched_shapes() {
        let spec = LayerSpec::new(4, 8, 8, 4);
        let img = Tensor::<u8>::zeros(&[4, 8, 8]);
        let wts = Tensor::<u8>::zeros(&[4, 4, 3, 3]);
        let bias = vec![0i32; 4];
        let wrong_spec = LayerSpec::new(8, 8, 8, 4);
        let err = GoldenBackend::new().run(&JobPayload {
            kind: JobKind::Standard,
            spec: &wrong_spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        });
        assert!(err.is_err());
    }

    #[test]
    fn rejects_short_bias_instead_of_panicking() {
        // A bias shorter than K must surface as Err from the shared
        // payload validation, not as an index panic inside the kernel.
        let spec = LayerSpec::new(4, 8, 8, 4);
        let img = Tensor::<u8>::zeros(&[4, 8, 8]);
        let wts = Tensor::<u8>::zeros(&[4, 4, 3, 3]);
        let bias = vec![0i32; 2];
        let err = GoldenBackend::new().run(&JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        });
        assert!(err.is_err());
    }

    #[test]
    fn reports_modelled_cost_as_cycles() {
        let spec = QUICKSTART;
        let img = Tensor::<u8>::zeros(&[spec.c, spec.h, spec.w]);
        let wts = Tensor::<u8>::zeros(&[spec.k, spec.c, 3, 3]);
        let bias = vec![0i32; spec.k];
        let mut be = GoldenBackend::new();
        let run = be
            .run(&JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .unwrap();
        assert_eq!(run.cycles.total, be.cost(&spec, JobKind::Standard));
    }
}
