//! Workload traces for the coordinator benches: streams of conv-layer
//! requests with configurable shape mix and arrival pattern.
//!
//! The paper evaluates a single fixed workload (§5.2). A serving system
//! needs mixed traffic, so the trace generator produces the shapes of
//! the edge CNN plus the paper's S52 layer in configurable proportions
//! — DESIGN.md's "synthetic equivalent of production traces" — and,
//! since the backend refactor, an optional fraction of depthwise
//! (MobileNet-style) jobs that exercise the pool's capability-masked
//! routing.

use super::{network::edge_cnn_specs, LayerSpec, S52};
use crate::backend::{job_psums, JobKind};
use crate::util::prng::Prng;

/// One trace entry: which layer shape arrives, what kind of conv it
/// is, and when (in microseconds of simulated wall clock from trace
/// start).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEntry {
    pub spec: LayerSpec,
    pub kind: JobKind,
    pub arrival_us: u64,
    pub seed: u64,
}

impl TraceEntry {
    /// Kind-aware PSUM count (matches the coordinator's accounting).
    pub fn psums(&self) -> u64 {
        job_psums(&self.spec, self.kind)
    }
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Total requests to generate.
    pub n: usize,
    /// Mean inter-arrival gap in microseconds (exponential-ish via
    /// uniform doubling; 0 = all arrive at t=0, a closed-loop burst).
    pub mean_gap_us: u64,
    /// Weight of the big S52 layer relative to edge-CNN layers
    /// (0.0 = only small layers, 1.0 = only S52).
    pub s52_fraction: f64,
    /// Fraction of depthwise (per-channel 3×3) jobs mixed into the
    /// stream (0.0 = none; drawn before the S52/edge split).
    pub depthwise_fraction: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n: 64,
            mean_gap_us: 0,
            s52_fraction: 0.25,
            depthwise_fraction: 0.0,
            seed: 1,
        }
    }
}

/// Depthwise shapes mirroring the edge CNN's intermediate maps
/// (`K == C`, the MobileNet-style blocks of `hw::depthwise`).
fn depthwise_specs() -> Vec<LayerSpec> {
    vec![
        LayerSpec::new(4, 32, 32, 4),
        LayerSpec::new(8, 15, 15, 8),
        LayerSpec::new(16, 13, 13, 16),
    ]
}

/// Generate a deterministic trace from a config.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceEntry> {
    let mut rng = Prng::new(cfg.seed);
    let small = edge_cnn_specs();
    let dw = depthwise_specs();
    let mut t = 0u64;
    (0..cfg.n)
        .map(|i| {
            // Draw the depthwise coin only when enabled, so traces from
            // older configs replay identically at depthwise_fraction=0.
            let is_dw = cfg.depthwise_fraction > 0.0 && rng.f64() < cfg.depthwise_fraction;
            let (spec, kind) = if is_dw {
                (*rng.choose(&dw), JobKind::Depthwise)
            } else if rng.f64() < cfg.s52_fraction {
                (S52, JobKind::Standard)
            } else {
                (*rng.choose(&small), JobKind::Standard)
            };
            if cfg.mean_gap_us > 0 {
                // Uniform in [0, 2*mean] has the right mean and keeps the
                // trace integer-deterministic.
                t += rng.below(2 * cfg.mean_gap_us + 1);
            }
            TraceEntry {
                spec,
                kind,
                arrival_us: t,
                seed: cfg.seed ^ (i as u64) << 1,
            }
        })
        .collect()
}

/// Total PSUMs in a trace (the paper's throughput accounting unit),
/// kind-aware.
pub fn total_psums(trace: &[TraceEntry]) -> u64 {
    trace.iter().map(|e| e.psums()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = TraceConfig::default();
        assert_eq!(generate(&cfg), generate(&cfg));
    }

    #[test]
    fn arrivals_are_monotone() {
        let cfg = TraceConfig {
            mean_gap_us: 100,
            n: 50,
            ..Default::default()
        };
        let t = generate(&cfg);
        for pair in t.windows(2) {
            assert!(pair[0].arrival_us <= pair[1].arrival_us);
        }
    }

    #[test]
    fn fraction_extremes() {
        let only_s52 = generate(&TraceConfig {
            s52_fraction: 1.0,
            ..Default::default()
        });
        assert!(only_s52.iter().all(|e| e.spec == S52));
        let none = generate(&TraceConfig {
            s52_fraction: 0.0,
            ..Default::default()
        });
        assert!(none.iter().all(|e| e.spec != S52));
    }

    #[test]
    fn psum_totals_add_up() {
        let t = generate(&TraceConfig {
            n: 3,
            s52_fraction: 1.0,
            ..Default::default()
        });
        assert_eq!(total_psums(&t), 3 * S52.psums());
    }

    #[test]
    fn depthwise_fraction_extremes() {
        let all_dw = generate(&TraceConfig {
            n: 40,
            depthwise_fraction: 1.0,
            ..Default::default()
        });
        assert!(all_dw.iter().all(|e| e.kind == JobKind::Depthwise));
        assert!(all_dw.iter().all(|e| e.spec.k == e.spec.c));
        let none = generate(&TraceConfig {
            n: 40,
            depthwise_fraction: 0.0,
            ..Default::default()
        });
        assert!(none.iter().all(|e| e.kind == JobKind::Standard));
    }

    #[test]
    fn depthwise_psums_have_no_kernel_axis() {
        let e = TraceEntry {
            spec: LayerSpec::new(8, 10, 10, 8),
            kind: JobKind::Depthwise,
            arrival_us: 0,
            seed: 0,
        };
        assert_eq!(e.psums(), 64 * 8);
    }
}
