//! TCP front-end speaking **wire protocol v2**: newline-delimited JSON
//! over a socket — the network face an edge gateway or a remote
//! coordinator ([`crate::backend::RemoteBackend`]) talks to, in front
//! of the same batcher + heterogeneous core pool the in-process server
//! uses.
//!
//! # Protocol v2 specification
//!
//! One JSON object per line in both directions. Four frame types:
//!
//! ## `hello` (server → client, first line after connect)
//!
//! The server introduces itself before reading anything, advertising
//! every pool worker's capability so a remote coordinator can mask and
//! weigh this peer honestly:
//!
//! ```text
//! <- {"hello":{"proto":2,"freq_hz":112000000,"cores":3,"workers":[
//!      {"backend":"sim-ipcore-i32","standard":true,"depthwise":true,
//!       "pointwise":true,"accum":"i32","model":"sim-cycles","quote":6272},
//!      ...]}}
//! ```
//!
//! `proto` is the protocol revision (clients must reject anything but
//! 2). `model` is the worker's cost-model family
//! ([`crate::backend::CostModel::family_tag`]) — a remote coordinator
//! prices this pool's compute by its fastest advertised tier, so a
//! host-workers-only peer is never mistaken for a rack of IP cores.
//! `quote` is the worker's own cost-model estimate for the reference
//! [`QUICKSTART`] standard job, in that backend's own units —
//! observability for the mix, not a cross-backend comparable number.
//!
//! ## request (client → server)
//!
//! ```text
//! -> {"id":1,"spec":{"c":8,"h":16,"w":16,"k":8},"seed":42}
//! -> {"id":2,"kind":"depthwise","spec":{"c":8,"h":10,"w":10,"k":8,"relu":true},
//!     "seed":7,"full_output":true}
//! -> {"id":3,"kind":"pointwise","spec":{...},"img":[...C*H*W u8...],
//!     "weights":[...K*C*9 u8...],"bias":[...K i32...]}
//! ```
//!
//! * `kind` — `"standard"` (default), `"depthwise"` (weights `C*9`,
//!   bias `C`, requires `k == c`; ReLU fuses when `spec.relu`), or
//!   `"pointwise"` (a 1×1 conv pre-lowered to the 3×3 dataflow:
//!   padded image + centre-tapped weights, standard shapes on the
//!   wire). Pointwise jobs need explicit tensors — there is no
//!   synthetic pointwise generator.
//! * `seed` — synthesise deterministic tensors server-side (load
//!   generation); explicit `img`/`weights`/`bias` carry real data.
//! * `full_output` — opt into the whole output tensor in the reply.
//!   Off by default: a load generator only needs the checksum, and a
//!   v1 8-word head is useless for a backend that must return the
//!   tensor.
//!
//! The wire serves production traffic only: every job requires I32
//! accumulator semantics (wrap-8 replies stay an in-process,
//! experiment-side concern).
//!
//! ## reply (server → client)
//!
//! ```text
//! <- {"id":1,"ok":true,"kind":"standard","core":0,"backend":"sim-ipcore-i32",
//!     "compute_cycles":6272,"total_cycles":6272,"sim_us":56,
//!     "weights_reused":false,"output_head":[...8 words...],"checksum":1234567}
//! <- {"id":2,"ok":true,...,"shape":[8,8,8],"output":[...i32 words...]}
//! ```
//!
//! `shape`/`output` appear only when the request set `full_output`.
//! The checksum (sum of output words mod 2^31) always lets clients
//! verify numerics without shipping whole feature maps back.
//!
//! ## error (server → client)
//!
//! ```text
//! <- {"id":9,"ok":false,"error":"spec violates §4.1 (K%4!=0 or too small)"}
//! ```
//!
//! Malformed JSON, bad shapes, unservable kinds and *backend failures*
//! (e.g. this peer's own remote sub-peer dropping) all answer with an
//! error frame on the same id — a request never silently disappears.
//!
//! ## rejected (server → client)
//!
//! ```text
//! <- {"id":9,"ok":false,"rejected":true,
//!     "error":"admission: 2048 PSUMs would exceed the in-flight budget"}
//! ```
//!
//! Load shedding. When the server runs with an in-flight PSUM budget
//! ([`CoordinatorConfig::max_inflight_psums`]) and a request's cost
//! quote would blow it, the server answers *immediately* with
//! `"rejected":true` instead of queueing — the fast-error admission
//! answer. Clients that predate the field still see a well-formed
//! error frame (`ok:false`, same id); the extra key is ignored.
//!
//! ## `ping` (client → server) / `pong` (server → client) — negotiated
//!
//! ```text
//! -> {"ping":1}
//! <- {"pong":1}
//! ```
//!
//! Lightweight health probe (no `id`, echoes the ping's sequence
//! number). Feature-negotiated via the hello: a server that answers
//! pings advertises `"ping":true` inside its `hello` object; clients
//! must not send `ping` frames to peers whose hello lacks the flag
//! (plain v2 peers would treat them as malformed requests). Pings are
//! answered before admission control — probing a saturated server must
//! not be shed.
//!
//! # Version negotiation
//!
//! `proto` stays 2 — peers reject any other revision outright.
//! Capabilities *within* v2 are negotiated by the presence of hello
//! fields (`"ping":true` today): unknown hello fields, unknown request
//! fields and unknown reply fields must all be ignored, so a newer
//! server interoperates with an older client and vice versa.
//!
//! # Shutdown
//!
//! [`TcpServer::stop`] drains: it stops accepting, joins every
//! per-connection handler thread (handlers poll the shutdown flag on a
//! read timeout, so an idle keep-alive connection cannot block
//! shutdown), and only then shuts the worker pool down — in-flight
//! jobs complete and are answered before the pool dies.

use super::backpressure::{Admission, AdmissionController, Policy};
use super::config::CoordinatorConfig;
use super::dispatch::CorePool;
use super::request::{fnv1a_bytes, weights_fingerprint_salted, ConvJob, ConvResult, Submission};
use crate::backend::JobKind;
use crate::model::{LayerSpec, Tensor, QUICKSTART};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Protocol revision advertised in the `hello` frame.
pub const PROTO_VERSION: u64 = 2;

/// How often blocked connection readers wake to poll the shutdown flag.
const SHUTDOWN_POLL: Duration = Duration::from_millis(100);

/// Ceiling on one reply write; a client that stops draining its socket
/// loses the connection instead of wedging the handler thread.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Hard cap on one wire frame. An S52 `full_output` reply is ~5 MB of
/// JSON text, so 64 MB never trips legitimately — it bounds memory (and
/// guarantees eventual termination) against a peer that streams bytes
/// without ever sending a newline, which would otherwise defeat the
/// read-timeout shutdown poll and grow the line buffer forever.
pub(crate) const MAX_LINE_BYTES: usize = 64 << 20;

/// Outcome of one bounded line read.
pub(crate) enum LineRead {
    /// A full line is buffered in `buf` (newline consumed, excluded).
    Line,
    /// Clean end of stream.
    Eof,
}

/// `read_line` with a hard byte cap, accumulating into `buf` across
/// calls: a read timeout surfaces as `Err` (`WouldBlock`/`TimedOut`)
/// with every byte read so far preserved in `buf`, so retrying
/// continues the same line; a line longer than `cap` fails with
/// `InvalidData` instead of growing without bound.
pub(crate) fn read_line_capped<R: BufRead>(
    r: &mut R,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<LineRead> {
    loop {
        let (found, n) = {
            let available = r.fill_buf()?;
            if available.is_empty() {
                return Ok(LineRead::Eof);
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    buf.extend_from_slice(&available[..i]);
                    (true, i + 1)
                }
                None => {
                    buf.extend_from_slice(available);
                    (false, available.len())
                }
            }
        };
        r.consume(n);
        if found {
            return Ok(LineRead::Line);
        }
        if buf.len() > cap {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("wire frame exceeds {cap} bytes without a newline"),
            ));
        }
    }
}

/// Running TCP server handle.
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    listener_thread: std::thread::JoinHandle<()>,
    shutdown: Arc<AtomicBool>,
    /// Chaos switch: while set, the accept loop drops new connections
    /// and [`Self::set_down`] has severed every live one.
    down: Arc<AtomicBool>,
    /// Per-connection handler threads, tracked so [`Self::stop`] can
    /// drain them instead of racing detached threads.
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    /// One monitor clone per live connection's socket, registered
    /// *before* the handler greets the client, so [`Self::set_down`]
    /// can sever every connection a client has seen a hello on. Each
    /// handler holds its monitor's other `Arc` until it exits, which is
    /// how the listener prunes dead entries (`strong_count == 1`).
    live: Arc<Mutex<Vec<Arc<TcpStream>>>>,
    /// In-flight PSUM budget (admission control), present when the
    /// config sets `max_inflight_psums`.
    admission: Option<Arc<AdmissionController>>,
    pool: Arc<CorePool>,
}

fn parse_spec(j: &Json) -> Result<LayerSpec, String> {
    let g = |k: &str| {
        j.get(&[k])
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("spec.{k} missing"))
    };
    let mut spec = LayerSpec::new(g("c")?, g("h")?, g("w")?, g("k")?);
    if j.get(&["relu"]).and_then(Json::as_bool).unwrap_or(false) {
        spec = spec.with_relu();
    }
    Ok(spec)
}

fn parse_kind(req: &Json) -> Result<JobKind, String> {
    match req.get(&["kind"]).and_then(Json::as_str) {
        None => Ok(JobKind::Standard),
        // One mapping, shared with the emit side: JobKind::tag().
        Some(s) => [
            JobKind::Standard,
            JobKind::Depthwise,
            JobKind::PointwiseAs3x3,
        ]
        .into_iter()
        .find(|k| k.tag() == s)
        .ok_or_else(|| format!("unknown kind '{s}' (expect standard|depthwise|pointwise)")),
    }
}

fn parse_u8_array(j: &Json, want_len: usize, name: &str) -> Result<Vec<u8>, String> {
    let arr = j.as_arr().ok_or_else(|| format!("{name} must be an array"))?;
    if arr.len() != want_len {
        return Err(format!("{name} length {} != {want_len}", arr.len()));
    }
    arr.iter()
        .map(|v| {
            v.as_f64()
                .filter(|n| (0.0..=255.0).contains(n))
                .map(|n| n as u8)
                .ok_or_else(|| format!("{name} element out of u8 range"))
        })
        .collect()
}

/// Build a ConvJob from one request line (any kind, v2 fields).
fn job_from_request(id: u64, req: &Json) -> Result<ConvJob, String> {
    let spec = parse_spec(req.get(&["spec"]).ok_or("missing spec")?)?;
    let kind = parse_kind(req)?;
    match kind {
        JobKind::Standard | JobKind::PointwiseAs3x3 => {
            if !spec.paper_compatible() {
                return Err(format!("spec violates §4.1 (K%4!=0 or too small): {spec:?}"));
            }
        }
        JobKind::Depthwise => {
            if spec.k != spec.c {
                return Err(format!("depthwise spec needs K == C: {spec:?}"));
            }
            if spec.h < 3 || spec.w < 3 {
                return Err(format!("depthwise spec too small for a 3x3 window: {spec:?}"));
            }
        }
    }
    // Output-channel count: K for standard/pointwise, C for depthwise.
    let out_ch = match kind {
        JobKind::Depthwise => spec.c,
        _ => spec.k,
    };
    if let Some(img_j) = req.get(&["img"]) {
        let img = parse_u8_array(img_j, spec.c * spec.h * spec.w, "img")?;
        let weight_len = match kind {
            JobKind::Depthwise => spec.c * 9,
            _ => spec.k * spec.c * 9,
        };
        let wts = parse_u8_array(
            req.get(&["weights"]).ok_or("missing weights")?,
            weight_len,
            "weights",
        )?;
        let bias_arr = req
            .get(&["bias"])
            .and_then(Json::as_arr)
            .ok_or("missing bias")?;
        if bias_arr.len() != out_ch {
            return Err(format!("bias length {} != {}", bias_arr.len(), out_ch));
        }
        let bias: Vec<i32> = bias_arr
            .iter()
            .map(|v| v.as_f64().map(|n| n as i32).ok_or("bias element"))
            .collect::<Result<_, _>>()?;
        let weights = match kind {
            JobKind::Depthwise => Tensor::from_vec(&[spec.c, 3, 3], wts),
            _ => Tensor::from_vec(&[spec.k, spec.c, 3, 3], wts),
        };
        // Explicit tensors: fingerprint the actual weight bytes (folded
        // into the FNV state as salt, so it can't alias a synthetic
        // per-spec set). Identical weights batched consecutively
        // legitimately skip the weight DMA; different weights never
        // share an id — request ids (which restart at 1 per client
        // connection) play no part, so two clients can't collide.
        let weights_id = weights_fingerprint_salted(&spec, kind, fnv1a_bytes(weights.data()));
        Ok(ConvJob {
            id,
            spec,
            kind,
            // The wire protocol serves production traffic only; wrap-8
            // replies stay an in-process (experiment) concern.
            accum: crate::hw::AccumMode::I32,
            img: Tensor::from_vec(&[spec.c, spec.h, spec.w], img),
            weights,
            bias,
            weights_id,
        })
    } else {
        let seed = req
            .get(&["seed"])
            .and_then(Json::as_f64)
            .ok_or("need seed or img/weights/bias")? as u64;
        match kind {
            JobKind::Standard => Ok(ConvJob::synthetic(id, spec, seed)),
            JobKind::Depthwise => Ok(ConvJob::synthetic_depthwise(id, spec, seed)),
            JobKind::PointwiseAs3x3 => {
                Err("pointwise jobs need explicit pre-lowered tensors, not a seed".into())
            }
        }
    }
}

fn response_json(r: &ConvResult, freq_hz: u64, full_output: bool) -> Json {
    if let Some(err) = &r.error {
        return error_json(r.id, err);
    }
    let head: Vec<i64> = r.output.data().iter().take(8).map(|&v| v as i64).collect();
    let checksum = r
        .output
        .data()
        .iter()
        .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
    let mut fields = vec![
        ("id", Json::num(r.id as f64)),
        ("ok", Json::Bool(true)),
        ("kind", Json::str(r.kind.tag())),
        ("core", Json::num(r.core as f64)),
        ("backend", Json::str(r.backend)),
        ("compute_cycles", Json::num(r.cycles.compute as f64)),
        ("total_cycles", Json::num(r.cycles.total as f64)),
        (
            "sim_us",
            Json::num((r.cycles.total as f64 / freq_hz as f64 * 1e6).round()),
        ),
        ("weights_reused", Json::Bool(r.weights_reused)),
        ("output_head", Json::arr_i64(head)),
        ("checksum", Json::num(checksum as f64)),
    ];
    if full_output {
        fields.push((
            "shape",
            Json::arr_u64(r.output.shape().iter().map(|&d| d as u64)),
        ));
        fields.push((
            "output",
            Json::arr_i64(r.output.data().iter().map(|&v| v as i64)),
        ));
    }
    Json::obj(fields)
}

fn error_json(id: u64, msg: &str) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

/// The capability advertisement every connection opens with.
fn hello_json(pool: &CorePool) -> Json {
    let quotes = pool.worker_cost_models();
    let workers: Vec<Json> = pool
        .worker_capabilities()
        .iter()
        .zip(&quotes)
        .map(|((name, cap), cost)| {
            Json::obj(vec![
                ("backend", Json::str(*name)),
                ("standard", Json::Bool(cap.standard3x3)),
                ("depthwise", Json::Bool(cap.depthwise)),
                ("pointwise", Json::Bool(cap.pointwise_as_3x3)),
                (
                    "accum",
                    Json::str(match cap.accum {
                        crate::hw::AccumMode::I32 => "i32",
                        crate::hw::AccumMode::Wrap8 => "wrap8",
                    }),
                ),
                ("model", Json::str(cost.family_tag())),
                (
                    "quote",
                    Json::num(cost.cost(&QUICKSTART, JobKind::Standard) as f64),
                ),
            ])
        })
        .collect();
    Json::obj(vec![(
        "hello",
        Json::obj(vec![
            ("proto", Json::num(PROTO_VERSION as f64)),
            // In-revision feature flag (see "Version negotiation"):
            // this server answers `ping` control frames.
            ("ping", Json::Bool(true)),
            ("freq_hz", Json::num(pool.ip_config().freq_hz as f64)),
            ("cores", Json::num(pool.n_cores() as f64)),
            ("workers", Json::Arr(workers)),
        ]),
    )])
}

/// Parse, dispatch and answer one request line.
fn process_line(
    line: &str,
    pool: &CorePool,
    fallback_id: u64,
    freq: u64,
    admission: Option<&AdmissionController>,
) -> Json {
    let req = match Json::parse(line) {
        Err(e) => return error_json(fallback_id, &format!("bad json: {e}")),
        Ok(req) => req,
    };
    // Ping control frame: answered before job parsing and before
    // admission — a health probe must stay cheap and is never shed.
    if let Some(seq) = req.get(&["ping"]).and_then(Json::as_f64) {
        return Json::obj(vec![("pong", Json::num(seq))]);
    }
    let req_id = req
        .get(&["id"])
        .and_then(Json::as_f64)
        .map(|n| n as u64)
        .unwrap_or(fallback_id);
    let full_output = req
        .get(&["full_output"])
        .and_then(Json::as_bool)
        .unwrap_or(false);
    let job = match job_from_request(req_id, &req) {
        Err(e) => return error_json(req_id, &e),
        Ok(job) => job,
    };
    // Admission control gates on the job's PSUM quote (the unit the
    // dispatcher balances by) with the fast-reject serving policy: an
    // over-budget request gets a `rejected` frame now, not a queue slot.
    let psums = job.psums();
    if let Some(ac) = admission {
        if ac.admit(psums, Policy::Reject) == Admission::Rejected {
            pool.metrics.record_shed();
            let msg = format!(
                "admission: {psums} PSUMs would exceed the in-flight budget ({}/{} in flight)",
                ac.inflight(),
                ac.capacity()
            );
            return Json::obj(vec![
                ("id", Json::num(req_id as f64)),
                ("ok", Json::Bool(false)),
                ("rejected", Json::Bool(true)),
                ("error", Json::str(&msg)),
            ]);
        }
    }
    let (tx, rx) = channel();
    let spec = job.spec;
    let weights_id = job.weights_id;
    let kind = job.kind;
    let accum = job.accum;
    let batch = super::batcher::Batch {
        spec,
        weights_id,
        kind,
        accum,
        jobs: vec![Submission {
            job,
            reply: tx,
            enqueued: std::time::Instant::now(),
        }],
    };
    // An unroutable job (e.g. depthwise against a standard-only pool)
    // is a client error on the wire, not a deployment panic.
    if let Err(back) = pool.try_dispatch(batch) {
        if let Some(ac) = admission {
            ac.complete(psums);
        }
        return error_json(
            req_id,
            &format!(
                "no backend in this pool serves {:?} jobs in {:?} accum mode",
                back.kind, back.accum
            ),
        );
    }
    let reply = match rx.recv() {
        Ok(result) => response_json(&result, freq, full_output),
        Err(_) => error_json(req_id, "worker dropped"),
    };
    if let Some(ac) = admission {
        ac.complete(psums);
    }
    reply
}

fn handle_connection(
    stream: TcpStream,
    pool: Arc<CorePool>,
    next_id: Arc<AtomicU64>,
    hello_line: Arc<String>,
    shutdown: Arc<AtomicBool>,
    down: Arc<AtomicBool>,
    admission: Option<Arc<AdmissionController>>,
    // Held (not used) until this handler returns: the listener prunes
    // the chaos-kill registry by the monitor's refcount.
    _monitor: Arc<TcpStream>,
) {
    let freq = pool.ip_config().freq_hz;
    stream.set_nodelay(true).ok();
    // Readers wake periodically to poll the shutdown flag, so stop()
    // can drain handlers even while clients hold idle connections open.
    stream.set_read_timeout(Some(SHUTDOWN_POLL)).ok();
    // Bounded writes too: a client that stops reading a multi-megabyte
    // full_output reply must fail its connection, not park this handler
    // (and block stop()) on a full TCP send buffer forever.
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    if writeln!(writer, "{hello_line}").is_err() {
        return;
    }
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shutdown.load(Ordering::Relaxed) || down.load(Ordering::Relaxed) {
            break;
        }
        match read_line_capped(&mut reader, &mut buf, MAX_LINE_BYTES) {
            Ok(LineRead::Eof) => break, // client closed the connection
            Ok(LineRead::Line) => {
                let reply = {
                    let line = String::from_utf8_lossy(&buf);
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        None
                    } else {
                        let id = next_id.fetch_add(1, Ordering::Relaxed);
                        Some(process_line(trimmed, &pool, id, freq, admission.as_deref()))
                    }
                };
                buf.clear();
                if let Some(reply) = reply {
                    if writeln!(writer, "{}", reply.to_json()).is_err() {
                        break;
                    }
                }
            }
            // Read timeout: loop to re-check shutdown. Partial-line
            // bytes stay accumulated in `buf`, so mid-line timeouts
            // lose nothing.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            // Includes an over-cap frame: drop the connection.
            Err(_) => break,
        }
    }
}

impl TcpServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port). The
    /// pool is whatever the config describes — simulated IP cores,
    /// golden / im2col host workers, even this peer's own remote peers.
    pub fn start(addr: &str, config: CoordinatorConfig) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let pool = Arc::new(super::server::build_pool(&config)?);
        let admission = config
            .max_inflight_psums
            .map(|m| Arc::new(AdmissionController::new(m)));
        let hello_line = Arc::new(hello_json(&pool).to_json());
        let next_id = Arc::new(AtomicU64::new(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        let down = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let live: Arc<Mutex<Vec<Arc<TcpStream>>>> = Arc::new(Mutex::new(Vec::new()));
        let shutdown_flag = Arc::clone(&shutdown);
        let down_flag = Arc::clone(&down);
        let conns_in_listener = Arc::clone(&conns);
        let live_in_listener = Arc::clone(&live);
        let pool_in_listener = Arc::clone(&pool);
        let admission_in_listener = admission.clone();
        listener.set_nonblocking(true)?;
        let listener_thread = std::thread::Builder::new()
            .name("repro-tcp".into())
            .spawn(move || {
                loop {
                    if shutdown_flag.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // Chaos: a "dead" peer accepts nothing. The
                            // socket closes without a hello, which a
                            // dialing client reads as connection refused.
                            if down_flag.load(Ordering::Relaxed) {
                                drop(stream);
                                continue;
                            }
                            stream.set_nonblocking(false).ok();
                            let monitor = match stream.try_clone() {
                                Ok(m) => Arc::new(m),
                                Err(_) => continue,
                            };
                            // Register the monitor before the handler
                            // can greet: once a client sees the hello,
                            // set_down is guaranteed to find (and can
                            // sever) this connection.
                            {
                                let mut live = live_in_listener.lock().unwrap();
                                live.retain(|s| Arc::strong_count(s) > 1);
                                live.push(Arc::clone(&monitor));
                            }
                            let pool = Arc::clone(&pool_in_listener);
                            let next_id = Arc::clone(&next_id);
                            let hello = Arc::clone(&hello_line);
                            let shutdown = Arc::clone(&shutdown_flag);
                            let down = Arc::clone(&down_flag);
                            let admission = admission_in_listener.clone();
                            let handle = std::thread::spawn(move || {
                                handle_connection(
                                    stream, pool, next_id, hello, shutdown, down, admission,
                                    monitor,
                                )
                            });
                            let mut conns = conns_in_listener.lock().unwrap();
                            // Reap finished handlers so long-lived
                            // servers don't accumulate dead handles.
                            conns.retain(|h| !h.is_finished());
                            conns.push(handle);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(TcpServer {
            addr: local,
            listener_thread,
            shutdown,
            down,
            conns,
            live,
            admission,
            pool,
        })
    }

    /// The capability line every connection is greeted with (tests and
    /// observability).
    pub fn hello(&self) -> Json {
        hello_json(&self.pool)
    }

    /// This server's serving metrics (chaos harnesses and tests assert
    /// per-peer completion/shed counts through this).
    pub fn metrics(&self) -> Arc<super::metrics::Metrics> {
        Arc::clone(&self.pool.metrics)
    }

    /// The admission controller, when the config set an in-flight PSUM
    /// budget (tests pre-load it to exercise shedding deterministically).
    pub fn admission(&self) -> Option<Arc<AdmissionController>> {
        self.admission.clone()
    }

    /// Chaos hook: simulate this peer crashing (`down = true`) and
    /// coming back (`down = false`) without releasing the port. While
    /// down, every live connection is severed mid-stream and the accept
    /// loop drops new connections before the hello — exactly what a
    /// dialing client sees from a crashed process. Reviving restores
    /// service for *new* connections; severed ones stay dead (clients
    /// must redial, as they would after a real crash).
    pub fn set_down(&self, down: bool) {
        self.down.store(down, Ordering::Relaxed);
        if down {
            let live = self.live.lock().unwrap();
            for s in live.iter() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    /// Stop accepting, drain every connection handler (in-flight
    /// requests are answered first), then shut the pool down.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Unwedge any submitter parked on the admission Condvar before
        // joining handlers — a stopping server must not hang on its own
        // backpressure.
        if let Some(ac) = &self.admission {
            ac.shutdown();
        }
        let _ = self.listener_thread.join();
        loop {
            let handle = self.conns.lock().unwrap().pop();
            match handle {
                Some(h) => {
                    let _ = h.join();
                }
                None => break,
            }
        }
        // All other Arc holders have exited; shut the workers down
        // cleanly rather than leaking them to process teardown.
        if let Ok(pool) = Arc::try_unwrap(self.pool) {
            pool.shutdown();
        }
    }
}

/// Blocking one-shot client (used by tests, examples and load
/// generators): connect, swallow the `hello` greeting, send one
/// request, return its reply.
pub fn request_once(addr: &std::net::SocketAddr, body: &Json) -> anyhow::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    writeln!(stream, "{}", body.to_json())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?; // hello frame
    let hello = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad hello: {e}"))?;
    anyhow::ensure!(
        hello.get(&["hello"]).is_some(),
        "server did not open with a hello frame"
    );
    line.clear();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::depthwise::golden_depthwise3x3;
    use crate::model::{golden, QUICKSTART};
    use crate::util::prng::Prng;

    fn start_n(cores: usize) -> TcpServer {
        TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(cores),
        )
        .expect("bind")
    }

    fn start() -> TcpServer {
        start_n(2)
    }

    /// Raw client helper: connect, return (hello frame, stream, reader).
    fn connect_raw(
        addr: std::net::SocketAddr,
    ) -> (Json, TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        (Json::parse(&line).unwrap(), stream, reader)
    }

    #[test]
    fn handshake_advertises_pool_capability() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default()
                .with_cores(1)
                .with_im2col_workers(1),
        )
        .unwrap();
        let (hello, _stream, _reader) = connect_raw(server.addr);
        let h = hello.get(&["hello"]).expect("hello frame");
        assert_eq!(h.get(&["proto"]).unwrap().as_usize(), Some(2));
        // In-revision feature flag: this server answers pings.
        assert_eq!(h.get(&["ping"]).unwrap().as_bool(), Some(true));
        assert_eq!(h.get(&["cores"]).unwrap().as_usize(), Some(2));
        assert!(h.get(&["freq_hz"]).unwrap().as_f64().unwrap() > 0.0);
        let workers = h.get(&["workers"]).unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        let names: Vec<&str> = workers
            .iter()
            .map(|w| w.get(&["backend"]).unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["sim-ipcore-i32", "im2col-cpu"]);
        let models: Vec<&str> = workers
            .iter()
            .map(|w| w.get(&["model"]).unwrap().as_str().unwrap())
            .collect();
        assert_eq!(models, vec!["sim-cycles", "im2col"]);
        for w in workers {
            assert_eq!(w.get(&["accum"]).unwrap().as_str(), Some("i32"));
            assert_eq!(w.get(&["depthwise"]).unwrap().as_bool(), Some(true));
            assert!(w.get(&["quote"]).unwrap().as_f64().unwrap() >= 1.0);
        }
        server.stop();
    }

    #[test]
    fn seed_request_round_trips() {
        let server = start();
        let req = Json::parse(
            r#"{"id":7,"spec":{"c":8,"h":16,"w":16,"k":8},"seed":42}"#,
        )
        .unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true));
        assert_eq!(resp.get(&["id"]).unwrap().as_usize(), Some(7));
        assert_eq!(resp.get(&["kind"]).unwrap().as_str(), Some("standard"));
        assert_eq!(
            resp.get(&["compute_cycles"]).unwrap().as_usize(),
            Some(6272)
        );
        // No full output unless asked for.
        assert!(resp.get(&["output"]).is_none());
        // Checksum matches a local recomputation of the same seed.
        let job = ConvJob::synthetic(7, QUICKSTART, 42);
        let want = golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false);
        let checksum = want
            .data()
            .iter()
            .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
        assert_eq!(
            resp.get(&["checksum"]).unwrap().as_f64(),
            Some(checksum as f64)
        );
        server.stop();
    }

    #[test]
    fn explicit_tensor_request_computes() {
        let server = start();
        // 1-channel 4x4 image, 4 kernels: small enough to inline.
        let img: Vec<u64> = (0..16).collect();
        let wts: Vec<u64> = (0..36).map(|i| i % 5).collect();
        let req = Json::obj(vec![
            ("id", Json::num(1u32)),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(1u32)),
                    ("h", Json::num(4u32)),
                    ("w", Json::num(4u32)),
                    ("k", Json::num(4u32)),
                ]),
            ),
            ("img", Json::arr_u64(img.clone())),
            ("weights", Json::arr_u64(wts.clone())),
            ("bias", Json::arr_i64([0, 0, 0, 0])),
        ]);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        // Verify output head against golden.
        let img_t = Tensor::from_vec(&[1, 4, 4], img.iter().map(|&v| v as u8).collect());
        let wts_t = Tensor::from_vec(&[4, 1, 3, 3], wts.iter().map(|&v| v as u8).collect());
        let want = golden::conv3x3_i32(&img_t, &wts_t, &[0; 4], false);
        let head = resp.get(&["output_head"]).unwrap().as_arr().unwrap();
        for (a, b) in head.iter().zip(want.data()) {
            assert_eq!(a.as_f64().unwrap() as i32, *b);
        }
        server.stop();
    }

    #[test]
    fn full_output_round_trips_the_whole_tensor() {
        let server = start();
        let spec = LayerSpec::new(2, 5, 5, 4);
        let mut rng = Prng::new(91);
        let img = rng.bytes_below(spec.c * spec.h * spec.w, 256);
        let wts = rng.bytes_below(spec.k * spec.c * 9, 256);
        let bias: Vec<i64> = (0..spec.k).map(|_| rng.range_i64(-20, 20)).collect();
        let req = Json::obj(vec![
            ("id", Json::num(5u32)),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(2u32)),
                    ("h", Json::num(5u32)),
                    ("w", Json::num(5u32)),
                    ("k", Json::num(4u32)),
                ]),
            ),
            ("img", Json::arr_u64(img.iter().map(|&v| v as u64))),
            ("weights", Json::arr_u64(wts.iter().map(|&v| v as u64))),
            ("bias", Json::arr_i64(bias.clone())),
            ("full_output", Json::Bool(true)),
        ]);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        let shape: Vec<usize> = resp
            .get(&["shape"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![4, 3, 3]);
        let got: Vec<i32> = resp
            .get(&["output"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let img_t = Tensor::from_vec(&[2, 5, 5], img);
        let wts_t = Tensor::from_vec(&[4, 2, 3, 3], wts);
        let bias_i32: Vec<i32> = bias.iter().map(|&b| b as i32).collect();
        let want = golden::conv3x3_i32(&img_t, &wts_t, &bias_i32, false);
        assert_eq!(got, want.data(), "full tensor must survive the wire");
        server.stop();
    }

    #[test]
    fn depthwise_over_the_wire_matches_golden() {
        let server = start();
        let c = 8usize;
        let (h, w) = (10usize, 10usize);
        let mut rng = Prng::new(92);
        let img = rng.bytes_below(c * h * w, 256);
        let wts = rng.bytes_below(c * 9, 256);
        let bias: Vec<i64> = (0..c).map(|_| rng.range_i64(-100, 100)).collect();
        let req = Json::obj(vec![
            ("id", Json::num(6u32)),
            ("kind", Json::str("depthwise")),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(c as u32)),
                    ("h", Json::num(h as u32)),
                    ("w", Json::num(w as u32)),
                    ("k", Json::num(c as u32)),
                    ("relu", Json::Bool(true)),
                ]),
            ),
            ("img", Json::arr_u64(img.iter().map(|&v| v as u64))),
            ("weights", Json::arr_u64(wts.iter().map(|&v| v as u64))),
            ("bias", Json::arr_i64(bias.clone())),
            ("full_output", Json::Bool(true)),
        ]);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get(&["kind"]).unwrap().as_str(), Some("depthwise"));
        let got: Vec<i32> = resp
            .get(&["output"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let img_t = Tensor::from_vec(&[c, h, w], img);
        let wts_t = Tensor::from_vec(&[c, 3, 3], wts);
        let bias_i32: Vec<i32> = bias.iter().map(|&b| b as i32).collect();
        let want = golden_depthwise3x3(&img_t, &wts_t, &bias_i32, true);
        assert_eq!(got, want.data(), "depthwise+relu must survive the wire");
        server.stop();
    }

    #[test]
    fn synthetic_depthwise_seed_request_works() {
        let server = start();
        let req = Json::parse(
            r#"{"id":8,"kind":"depthwise","spec":{"c":8,"h":10,"w":10,"k":8},"seed":3}"#,
        )
        .unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        let job = ConvJob::synthetic_depthwise(8, LayerSpec::new(8, 10, 10, 8), 3);
        let want = golden_depthwise3x3(&job.img, &job.weights, &job.bias, false);
        let checksum = want
            .data()
            .iter()
            .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
        assert_eq!(
            resp.get(&["checksum"]).unwrap().as_f64(),
            Some(checksum as f64)
        );
        server.stop();
    }

    #[test]
    fn explicit_weight_sets_fingerprint_by_bytes_not_request_id() {
        // Request ids restart at 1 per client connection, so they must
        // play no part in the weight fingerprint: same weight bytes
        // share an id (legitimate DMA reuse), different bytes never do.
        let req = |id: u64, w0: u64| {
            Json::obj(vec![
                ("id", Json::num(id as f64)),
                (
                    "spec",
                    Json::obj(vec![
                        ("c", Json::num(1u32)),
                        ("h", Json::num(4u32)),
                        ("w", Json::num(4u32)),
                        ("k", Json::num(4u32)),
                    ]),
                ),
                ("img", Json::arr_u64(vec![0u64; 16])),
                (
                    "weights",
                    Json::arr_u64((0..36u64).map(|i| if i == 0 { w0 } else { 1 })),
                ),
                ("bias", Json::arr_i64([0, 0, 0, 0])),
            ])
        };
        let a = job_from_request(1, &req(1, 5)).unwrap();
        let b = job_from_request(2, &req(2, 5)).unwrap();
        let c = job_from_request(3, &req(3, 6)).unwrap();
        assert_eq!(a.weights_id, b.weights_id, "same bytes, different request ids");
        assert_ne!(a.weights_id, c.weights_id, "different bytes must never alias");
    }

    #[test]
    fn bad_requests_get_errors_not_disconnects() {
        let server = start();
        for bad in [
            "not json at all",
            r#"{"id":1}"#,
            r#"{"id":2,"spec":{"c":4,"h":8,"w":8,"k":6},"seed":1}"#, // K%4
            r#"{"id":3,"spec":{"c":1,"h":4,"w":4,"k":4},"img":[1,2,3]}"#, // short
            r#"{"id":4,"kind":"depthwise","spec":{"c":4,"h":8,"w":8,"k":8},"seed":1}"#, // K != C
            r#"{"id":5,"kind":"pointwise","spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#, // no synth
            r#"{"id":6,"kind":"transposed","spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#,
        ] {
            let mut stream = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap(); // hello
            writeln!(stream, "{bad}").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{bad}");
            assert!(resp.get(&["error"]).is_some());
        }
        server.stop();
    }

    #[test]
    fn multiple_requests_per_connection() {
        let server = start();
        let (_hello, mut stream, reader) = connect_raw(server.addr);
        for i in 0..3 {
            writeln!(
                stream,
                r#"{{"id":{i},"spec":{{"c":4,"h":8,"w":8,"k":4}},"seed":{i}}}"#
            )
            .unwrap();
        }
        let mut seen = Vec::new();
        for line in reader.lines().take(3) {
            let resp = Json::parse(&line.unwrap()).unwrap();
            assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true));
            seen.push(resp.get(&["id"]).unwrap().as_usize().unwrap());
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2]);
        drop(stream);
        server.stop();
    }

    #[test]
    fn ping_round_trips_a_pong() {
        let server = start_n(1);
        let (_hello, mut stream, mut reader) = connect_raw(server.addr);
        writeln!(stream, r#"{{"ping":7}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get(&["pong"]).unwrap().as_usize(), Some(7));
        assert!(resp.get(&["id"]).is_none(), "pongs carry no id");
        // The connection still serves normal requests afterwards.
        writeln!(stream, r#"{{"id":1,"spec":{{"c":4,"h":8,"w":8,"k":4}},"seed":1}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true));
        server.stop();
    }

    #[test]
    fn over_budget_request_gets_fast_rejected_frame() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig {
                max_inflight_psums: Some(100),
                ..CoordinatorConfig::default().with_cores(1)
            },
        )
        .unwrap();
        let ac = server.admission().expect("budgeted server has a controller");
        // Deterministically saturate the budget, as concurrent in-flight
        // work would.
        use crate::coordinator::backpressure::{Admission, Policy};
        assert_eq!(ac.admit(100, Policy::Reject), Admission::Admitted);
        let req = Json::parse(r#"{"id":3,"spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#).unwrap();
        let t0 = std::time::Instant::now();
        let resp = request_once(&server.addr, &req).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "rejection must be fast, not queued"
        );
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{resp:?}");
        assert_eq!(resp.get(&["rejected"]).unwrap().as_bool(), Some(true));
        assert_eq!(resp.get(&["id"]).unwrap().as_usize(), Some(3));
        assert!(resp
            .get(&["error"])
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("admission:"));
        assert_eq!(server.metrics().shed.load(Ordering::Relaxed), 1);
        // Budget frees -> the same request is served.
        ac.complete(100);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(ac.inflight(), 0, "served request released its charge");
        server.stop();
    }

    #[test]
    fn set_down_severs_connections_and_revive_restores_service() {
        let server = start_n(1);
        let (_hello, _stream, mut reader) = connect_raw(server.addr);
        server.set_down(true);
        // The live connection is severed mid-stream: the client reads
        // EOF (or a reset), never a reply.
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "severed connection must not produce data: {line:?}");
        // New connections are dropped before the hello greeting.
        let s2 = TcpStream::connect(server.addr).unwrap();
        let mut r2 = BufReader::new(s2);
        let mut l2 = String::new();
        let n2 = r2.read_line(&mut l2).unwrap_or(0);
        assert_eq!(n2, 0, "a down server must not greet: {l2:?}");
        // Revive: fresh connections are served again.
        server.set_down(false);
        let req = Json::parse(r#"{"id":1,"spec":{"c":4,"h":8,"w":8,"k":4},"seed":1}"#).unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        server.stop();
    }

    #[test]
    fn stop_drains_idle_connections_instead_of_hanging() {
        let server = start_n(1);
        // An idle keep-alive client: no request, connection held open.
        let (_hello, stream, _reader) = connect_raw(server.addr);
        let t0 = std::time::Instant::now();
        server.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stop() must drain handlers via the shutdown poll, not block on the idle client"
        );
        drop(stream);
    }
}
