"""L1 correctness: Pallas conv3x3 vs the pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, dtypes-of-origin and flags;
``assert_allclose`` with rtol=0 — the inputs are exact small integers in
f32, so the kernel must match the oracle *bit-exactly* (any deviation
means the contraction order lost integer exactness, which would break
parity with the int8 hardware simulator on the rust side).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv3x3 import conv3x3, vmem_footprint_bytes
from compile.kernels.ref import conv3x3_ref, conv3x3_wrap8, maxpool2x2_ref

RNG = np.random.default_rng(1234)


def _rand_case(c, h, w, k, lo=-64, hi=64):
    img = RNG.integers(0, 128, (c, h, w)).astype(np.float32)
    wts = RNG.integers(lo, hi, (k, c, 3, 3)).astype(np.float32)
    bias = RNG.integers(-32, 32, (k,)).astype(np.float32)
    return jnp.array(img), jnp.array(wts), jnp.array(bias)


# --- fixed-shape smoke cases -------------------------------------------------


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize(
    "c,h,w,k",
    [
        (4, 8, 8, 4),  # minimal paper-shaped layer (everything /4)
        (8, 16, 16, 8),  # quickstart artifact shape
        (8, 15, 15, 16),  # edge CNN layer 2
        (16, 5, 5, 32),  # edge CNN layer 4 (tiny spatial)
        (1, 3, 3, 4),  # degenerate: one window, C not /4
        (3, 9, 7, 4),  # first-layer RGB (C=3, the paper's exception)
    ],
)
def test_conv_matches_ref(c, h, w, k, relu):
    img, wts, bias = _rand_case(c, h, w, k)
    out = conv3x3(img, wts, bias, relu=relu)
    ref = conv3x3_ref(img, wts, bias, relu=relu)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=0, atol=0)
    assert out.shape == (k, h - 2, w - 2)


def test_bias_is_output_bram_preload():
    """Paper §4.2: bias pre-loaded into output BRAMs == added to the sum."""
    img, wts, bias = _rand_case(4, 6, 6, 4)
    with_bias = conv3x3(img, wts, bias)
    without = conv3x3(img, wts, jnp.zeros_like(bias))
    np.testing.assert_allclose(
        np.array(with_bias), np.array(without) + np.array(bias)[:, None, None]
    )


def test_block_partition_invariance():
    """Result must not depend on the (kblk, cblk) decomposition — the
    paper's 4x4 split is a schedule, not a semantics change."""
    img, wts, bias = _rand_case(8, 10, 10, 8)
    base = conv3x3(img, wts, bias, kblk=4, cblk=2)
    for kblk, cblk in [(2, 2), (8, 8), (4, 4), (1, 1), (8, 1), (2, 8)]:
        out = conv3x3(img, wts, bias, kblk=kblk, cblk=cblk)
        np.testing.assert_allclose(np.array(out), np.array(base), rtol=0, atol=0)


def test_rejects_indivisible_kernel_count():
    img, wts, bias = _rand_case(4, 6, 6, 6)
    with pytest.raises(AssertionError, match="divisible"):
        conv3x3(img, wts, bias, kblk=4)


# --- hypothesis sweeps -------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    c=st.sampled_from([1, 2, 3, 4, 8, 12, 16]),
    hw=st.tuples(st.integers(3, 14), st.integers(3, 14)),
    k=st.sampled_from([4, 8, 12]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_sweep(c, hw, k, relu, seed):
    h, w = hw
    rng = np.random.default_rng(seed)
    img = jnp.array(rng.integers(0, 128, (c, h, w)).astype(np.float32))
    wts = jnp.array(rng.integers(-64, 64, (k, c, 3, 3)).astype(np.float32))
    bias = jnp.array(rng.integers(-32, 32, (k,)).astype(np.float32))
    out = conv3x3(img, wts, bias, relu=relu)
    ref = conv3x3_ref(img, wts, bias, relu=relu)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    dtype=st.sampled_from([np.int8, np.uint8, np.int16, np.float32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_dtypes(dtype, seed):
    """Inputs arriving as any integer/float dtype must produce the same
    exact result once promoted (the runtime always ships f32 carriers)."""
    rng = np.random.default_rng(seed)
    info_hi = 127 if dtype != np.uint8 else 255
    lo = 0 if dtype == np.uint8 else -64
    img = rng.integers(0, min(info_hi, 127), (4, 7, 7)).astype(dtype)
    wts = rng.integers(lo, 64, (4, 4, 3, 3)).astype(dtype)
    bias = rng.integers(lo, 64, (4,)).astype(dtype)
    out = conv3x3(jnp.array(img), jnp.array(wts), jnp.array(bias))
    ref = conv3x3_ref(
        jnp.array(img, jnp.float32), jnp.array(wts, jnp.float32), jnp.array(bias, jnp.float32)
    )
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=0, atol=0)


# --- Fig. 6 wrap-8 oracle ----------------------------------------------------

FIG6_WEIGHTS = np.array(
    [
        [0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08, 0x09],
        [0x91, 0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98, 0x99],
        [0x21, 0x22, 0x23, 0x24, 0x25, 0x26, 0x27, 0x28, 0x29],
        [0xB1, 0xB2, 0xB3, 0xB4, 0xB5, 0xB6, 0xB7, 0xB8, 0xB9],
    ],
    dtype=np.uint8,
).reshape(4, 1, 3, 3)

# psum columns read straight off the paper's Fig. 6 (first 9 windows).
FIG6_PSUMS = np.array(
    [
        [0x9B, 0xC8, 0xF5, 0x7C, 0xA9, 0xD6, 0x5D, 0x8A, 0xB7],
        [0x0B, 0x48, 0x85, 0x3C, 0x79, 0xB6, 0x6D, 0xAA, 0xE7],
        [0x7B, 0xC8, 0x15, 0xFC, 0x49, 0x96, 0x7D, 0xCA, 0x17],
        [0xEB, 0x48, 0xA5, 0xBC, 0x19, 0x76, 0x8D, 0xEA, 0x47],
    ],
    dtype=np.uint8,
)


def fig6_feature(height: int = 5, width: int = 5) -> np.ndarray:
    """The testbench feature implied by Fig. 6: a byte ramp, row stride 5."""
    return (np.arange(1, height * width + 1, dtype=np.uint16) & 0xFF).astype(
        np.uint8
    ).reshape(1, height, width)


def test_wrap8_oracle_reproduces_fig6():
    feat = fig6_feature()
    out = conv3x3_wrap8(feat, FIG6_WEIGHTS)  # (4, 3, 3)
    got = out.reshape(4, 9)
    np.testing.assert_array_equal(got, FIG6_PSUMS)


def test_wrap8_matches_wide_conv_mod_256():
    rng = np.random.default_rng(7)
    img = rng.integers(0, 256, (4, 6, 6)).astype(np.uint8)
    wts = rng.integers(0, 256, (4, 4, 3, 3)).astype(np.uint8)
    wrap = conv3x3_wrap8(img, wts)
    wide = np.array(
        conv3x3_ref(jnp.array(img, jnp.float32), jnp.array(wts, jnp.float32))
    ).astype(np.int64)
    np.testing.assert_array_equal(wrap, (wide % 256).astype(np.uint8))


# --- pooling oracle ----------------------------------------------------------


@pytest.mark.parametrize("h,w", [(4, 4), (5, 5), (13, 13), (3, 8)])
def test_maxpool_shapes_and_values(h, w):
    rng = np.random.default_rng(h * 100 + w)
    img = rng.standard_normal((4, h, w)).astype(np.float32)
    out = np.array(maxpool2x2_ref(jnp.array(img)))
    assert out.shape == (4, h // 2, w // 2)
    for c in range(4):
        for y in range(h // 2):
            for x in range(w // 2):
                assert out[c, y, x] == img[c, 2 * y : 2 * y + 2, 2 * x : 2 * x + 2].max()


# --- perf-model sanity -------------------------------------------------------


def test_vmem_footprint_monotone_and_small():
    small = vmem_footprint_bytes(8, 16, 16, 8)
    big = vmem_footprint_bytes(8, 224, 224, 8)
    assert small["total_bytes"] < big["total_bytes"]
    assert big["fits_vmem_16MiB"]  # the paper's own workload tiles into VMEM
    assert 0 < small["mxu_fill"] <= 1
