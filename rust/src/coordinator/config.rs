//! Coordinator configuration.

use crate::hw::IpCoreConfig;
use crate::paper::MAX_CORES_Z2;
use crate::telemetry::scrape::ScrapeServer;
use crate::telemetry::SpanSink;
use std::sync::Arc;

/// Batching policy (see [`super::batcher`]).
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Flush a partial batch after this many enqueued requests of other
    /// shapes have passed it (prevents starvation of rare shapes).
    pub max_skips: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 8,
            max_skips: 16,
        }
    }
}

/// Top-level coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Simulated IP cores (the paper deploys 1..=20 on a Pynq Z2).
    pub n_cores: usize,
    /// Host-CPU fallback workers (`backend::GoldenBackend`) appended to
    /// the pool after the IP cores — the heterogeneous-pool deployment:
    /// overflow and depthwise traffic can spill onto the PS instead of
    /// queueing behind the accelerators.
    pub golden_fallback_workers: usize,
    /// Threaded im2col+GEMM workers (`backend::Im2colBackend`) appended
    /// after the golden workers — the *serious* CPU fallback; each one
    /// fans its GEMM across [`Self::im2col_worker_threads`] threads and
    /// quotes `CostModel::Im2col` units to the dispatcher.
    pub im2col_workers: usize,
    /// Threads per im2col worker's scoped GEMM fan-out.
    pub im2col_worker_threads: usize,
    /// Remote peers (`host:port`), each dialled at pool construction
    /// and appended as one `backend::RemoteBackend` worker speaking
    /// wire protocol v4 (`coordinator::tcp`) — whole machines joining
    /// the pool behind the same capability-masked dispatch. An
    /// unreachable peer is a construction error, not a silent absence.
    pub remote_peers: Vec<String>,
    /// Pin a served wire endpoint to protocol v2: the `hello`
    /// advertises `proto:2` with no binary-frame flag, and binary-
    /// framed requests are refused with a clean per-job error. Fronts
    /// dialling such a peer transparently stay on v2 JSON tensors —
    /// this knob exists to *be* the legacy peer in mixed-protocol
    /// fleets (CI's mixed smoke leg, the negotiation tests), not for
    /// production use.
    pub wire_v2_only: bool,
    /// Capacity of the served endpoint's content-addressed weight store
    /// (wire v4), in BRAM36 blocks. `None` budgets the full Pynq Z2
    /// BRAM inventory (`hw::device::XC7Z020_CLG400.bram36`); tests pin
    /// it tiny to exercise LRU eviction. Ignored when
    /// [`Self::wire_v2_only`] is set — a v2 endpoint has no store.
    pub weight_store_bram36: Option<u64>,
    pub ip: IpCoreConfig,
    pub batch: BatchConfig,
    /// Backpressure: max in-flight simulated PSUMs (None = unbounded).
    /// Submissions beyond it block until the cores drain (Block policy;
    /// see `coordinator::backpressure` for Reject-style load shedding).
    pub max_inflight_psums: Option<u64>,
    /// Whole-network streaming ([`super::stream`]): how many images may
    /// be in flight at once. 1 serialises images (no pipelining, the
    /// §4.1 chained baseline); larger windows let layer k+1 of image i
    /// overlap layer k of image i+1 across the pool.
    pub stream_window: usize,
    /// Distributed-tracing sink. `None` (default) disables tracing
    /// entirely: no ids are minted, no spans recorded, no trace fields
    /// cross the wire. Shared by Arc so the front, the dispatcher, the
    /// remote clients and the exporter all write/read one ring.
    pub trace: Option<Arc<SpanSink>>,
    /// Live Prometheus scrape endpoint. `None` (default) serves no
    /// metrics port. The server is bound by the caller (so the addr is
    /// known before the run) and attached to the pool's scrape source
    /// when serving starts.
    pub scrape: Option<Arc<ScrapeServer>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n_cores: 1,
            golden_fallback_workers: 0,
            im2col_workers: 0,
            im2col_worker_threads: 4,
            remote_peers: Vec::new(),
            wire_v2_only: false,
            weight_store_bram36: None,
            ip: IpCoreConfig::default(),
            batch: BatchConfig::default(),
            max_inflight_psums: None,
            stream_window: 4,
            trace: None,
            scrape: None,
        }
    }
}

impl CoordinatorConfig {
    pub fn with_cores(mut self, n: usize) -> Self {
        assert!(
            (1..=MAX_CORES_Z2).contains(&n),
            "core count {n} outside the paper's 1..=20 deployment range"
        );
        self.n_cores = n;
        self
    }

    /// Append `n` golden-CPU fallback workers to the pool.
    pub fn with_golden_workers(mut self, n: usize) -> Self {
        self.golden_fallback_workers = n;
        self
    }

    /// Append `n` threaded im2col+GEMM workers to the pool.
    pub fn with_im2col_workers(mut self, n: usize) -> Self {
        self.im2col_workers = n;
        self
    }

    /// Threads each im2col worker fans its GEMM across (min 1).
    pub fn with_im2col_worker_threads(mut self, threads: usize) -> Self {
        self.im2col_worker_threads = threads.max(1);
        self
    }

    /// Append one remote peer (`host:port`) to dial into the pool.
    pub fn with_remote_peer(mut self, addr: impl Into<String>) -> Self {
        self.remote_peers.push(addr.into());
        self
    }

    /// Replace the remote peer list.
    pub fn with_remote_peers(mut self, peers: Vec<String>) -> Self {
        self.remote_peers = peers;
        self
    }

    /// Serve the TCP endpoint as a legacy wire-v2 peer (see
    /// [`Self::wire_v2_only`]).
    pub fn with_wire_v2_only(mut self) -> Self {
        self.wire_v2_only = true;
        self
    }

    /// Budget the served endpoint's weight store to `blocks` BRAM36
    /// blocks (see [`Self::weight_store_bram36`]).
    pub fn with_weight_store_bram36(mut self, blocks: u64) -> Self {
        self.weight_store_bram36 = Some(blocks);
        self
    }

    /// Bound the streaming front's in-flight-images window (min 1; see
    /// [`Self::stream_window`]).
    pub fn with_stream_window(mut self, window: usize) -> Self {
        self.stream_window = window.max(1);
        self
    }

    /// Enable distributed tracing into `sink` (see [`Self::trace`]).
    pub fn with_trace(mut self, sink: Arc<SpanSink>) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Attach a bound Prometheus scrape endpoint (see [`Self::scrape`]).
    pub fn with_scrape(mut self, scrape: Arc<ScrapeServer>) -> Self {
        self.scrape = Some(scrape);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_single_core_paper_config() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.n_cores, 1);
        assert_eq!(c.ip.freq_hz, crate::paper::FREQ_Z2_HZ);
    }

    #[test]
    fn with_cores_accepts_paper_range() {
        assert_eq!(CoordinatorConfig::default().with_cores(20).n_cores, 20);
    }

    #[test]
    fn golden_workers_default_to_zero_and_compose() {
        assert_eq!(CoordinatorConfig::default().golden_fallback_workers, 0);
        let c = CoordinatorConfig::default().with_cores(4).with_golden_workers(2);
        assert_eq!((c.n_cores, c.golden_fallback_workers), (4, 2));
    }

    #[test]
    fn im2col_workers_default_off_with_four_threads_and_compose() {
        let d = CoordinatorConfig::default();
        assert_eq!((d.im2col_workers, d.im2col_worker_threads), (0, 4));
        let c = CoordinatorConfig::default()
            .with_cores(2)
            .with_im2col_workers(3)
            .with_im2col_worker_threads(8);
        assert_eq!((c.im2col_workers, c.im2col_worker_threads), (3, 8));
        // Thread knob is clamped to at least one.
        assert_eq!(CoordinatorConfig::default().with_im2col_worker_threads(0).im2col_worker_threads, 1);
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn with_cores_rejects_21() {
        let _ = CoordinatorConfig::default().with_cores(21);
    }

    #[test]
    fn wire_v2_only_defaults_off_and_composes() {
        assert!(!CoordinatorConfig::default().wire_v2_only);
        assert!(CoordinatorConfig::default().with_wire_v2_only().wire_v2_only);
    }

    #[test]
    fn weight_store_budget_defaults_to_full_board_and_composes() {
        assert!(CoordinatorConfig::default().weight_store_bram36.is_none());
        let c = CoordinatorConfig::default().with_weight_store_bram36(1);
        assert_eq!(c.weight_store_bram36, Some(1));
    }

    #[test]
    fn stream_window_defaults_to_four_and_clamps_to_one() {
        assert_eq!(CoordinatorConfig::default().stream_window, 4);
        assert_eq!(CoordinatorConfig::default().with_stream_window(8).stream_window, 8);
        assert_eq!(CoordinatorConfig::default().with_stream_window(0).stream_window, 1);
    }

    #[test]
    fn trace_and_scrape_default_off_and_compose() {
        let d = CoordinatorConfig::default();
        assert!(d.trace.is_none() && d.scrape.is_none());
        let sink = Arc::new(SpanSink::new());
        let c = CoordinatorConfig::default().with_trace(Arc::clone(&sink));
        assert!(Arc::ptr_eq(c.trace.as_ref().unwrap(), &sink));
        let srv = Arc::new(ScrapeServer::bind("127.0.0.1:0").unwrap());
        let c = c.with_scrape(Arc::clone(&srv));
        assert!(Arc::ptr_eq(c.scrape.as_ref().unwrap(), &srv));
        srv.stop();
    }

    #[test]
    fn remote_peers_default_empty_and_compose() {
        assert!(CoordinatorConfig::default().remote_peers.is_empty());
        let c = CoordinatorConfig::default()
            .with_remote_peer("10.0.0.1:7420")
            .with_remote_peer("10.0.0.2:7420");
        assert_eq!(c.remote_peers, vec!["10.0.0.1:7420", "10.0.0.2:7420"]);
        let d = CoordinatorConfig::default()
            .with_remote_peers(vec!["a:1".into(), "b:2".into()]);
        assert_eq!(d.remote_peers.len(), 2);
    }
}
