//! Small self-contained utilities the offline build environment forces
//! in-tree: a deterministic PRNG (no `rand`), a JSON parser for the AOT
//! manifest (no `serde_json`), and CLI argument helpers (no `clap`).

pub mod cli;
pub mod json;
pub mod prng;
