//! L3 coordinator: the serving layer over the execution backends.
//!
//! The paper ships an IP core and leaves the system around it to "the
//! PS". This module is that system, built the way a deployable runtime
//! (vLLM-router-style) would be — and since the backend refactor it is
//! substrate-agnostic: everything below the batcher speaks
//! [`crate::backend::ConvBackend`], not `hw::IpCore` directly.
//!
//! * [`request`] — typed conv / inference requests and responses,
//!   kind-tagged (standard / depthwise / pointwise-as-3×3);
//! * [`batcher`] — groups same-(shape, weight-set, kind, accum)
//!   requests so a core keeps its weight BRAM layout (weight-stationary
//!   across a batch, amortising the weight DMA);
//! * [`dispatch`] — a pool of worker threads each owning a
//!   `Box<dyn ConvBackend>`: the paper's "20 cores on a fully-utilised
//!   Pynq Z2", naive golden or threaded im2col host workers
//!   ([`config::CoordinatorConfig::im2col_workers`]), or any mix.
//!   Routing is capability-masked (depthwise jobs only reach
//!   depthwise-capable backends; a job's required accumulator mode must
//!   match `Capability::accum`, so wrap-8 traffic only reaches wrap-8
//!   silicon) and least-loaded in each backend's own cost-model units;
//! * [`scheduler`] — chains CNN layers on one backend the way §4.1
//!   chains output BRAMs into the next layer's input (no DMA
//!   round-trip), applying inter-layer requantisation; generic over the
//!   backend;
//! * [`stream`] — the whole-network streaming front: walks a registry
//!   model's layer chain *across the pool* (capability-masked per
//!   layer, boundary transforms applied between hops) with a bounded
//!   window of images in flight, so consecutive images' layers overlap
//!   on different workers;
//! * [`metrics`] — request counters, simulated-cycle accounting, and a
//!   latency histogram;
//! * [`server`] — the closed-loop trace driver used by the benches and
//!   the end-to-end example; [`server::build_pool`] turns a
//!   [`CoordinatorConfig`] into the heterogeneous pool (sim cores,
//!   host workers, and one `backend::RemoteBackend` per
//!   `remote_peers` entry — whole TCP-served machines in the pool);
//! * [`tcp`] — the network face: wire protocol v4 (a capability-
//!   advertising `hello` handshake, kind-tagged requests, binary
//!   tensor frames, and a content-addressed weight store so repeated
//!   weights ship only on miss), negotiating down to v3 binary frames
//!   or legacy v2 newline-delimited JSON per peer, in front of the
//!   same pool. `repro fleet N` composes the two sides into a
//!   multi-machine demo.
//!
//! Everything is std-only (threads + mpsc): the offline build has no
//! tokio, and the workloads here are CPU-bound simulation, not I/O.

pub mod backpressure;
pub mod batcher;
pub mod config;
pub mod dispatch;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod stream;
pub mod tcp;

pub use config::CoordinatorConfig;
pub use dispatch::CorePool;
pub use scheduler::CnnScheduler;
pub use server::Server;
pub use stream::StreamScheduler;
