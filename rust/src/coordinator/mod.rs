//! L3 coordinator: the serving layer over the accelerator substrate.
//!
//! The paper ships an IP core and leaves the system around it to "the
//! PS". This module is that system, built the way a deployable runtime
//! (vLLM-router-style) would be:
//!
//! * [`request`] — typed conv / inference requests and responses;
//! * [`batcher`] — groups same-shape requests so a core keeps its
//!   weight BRAM layout (weight-stationary across a batch, amortising
//!   the weight DMA);
//! * [`dispatch`] — a pool of 1..=20 simulated IP cores, each a worker
//!   thread (the paper's "20 cores on a fully-utilised Pynq Z2");
//! * [`scheduler`] — chains CNN layers on one core the way §4.1 chains
//!   output BRAMs into the next layer's input (no DMA round-trip),
//!   applying inter-layer requantisation;
//! * [`metrics`] — request counters, simulated-cycle accounting, and a
//!   latency histogram;
//! * [`server`] — the closed-loop trace driver used by the benches and
//!   the end-to-end example.
//!
//! Everything is std-only (threads + mpsc): the offline build has no
//! tokio, and the workloads here are CPU-bound simulation, not I/O.

pub mod backpressure;
pub mod batcher;
pub mod config;
pub mod dispatch;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod tcp;

pub use config::CoordinatorConfig;
pub use dispatch::CorePool;
pub use scheduler::CnnScheduler;
pub use server::Server;
