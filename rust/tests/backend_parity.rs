//! Backend parity property tests: for identical integer inputs, every
//! `ConvBackend` must produce bit-identical i32 outputs — across random
//! paper-compatible specs, both special job kinds (depthwise and
//! pointwise-as-3×3), and, when the runtime is linked and artifacts
//! exist, the XLA path.
//!
//! In-tree PRNG harness (no proptest offline): every case reports its
//! seed so failures reproduce exactly.

use repro::backend::{ConvBackend, GoldenBackend, JobKind, JobPayload, SimBackend, XlaBackend};
use repro::hw::depthwise::{golden_pointwise, pad1, pointwise_as_3x3};
use repro::hw::IpCoreConfig;
use repro::model::{LayerSpec, Tensor};
use repro::util::prng::Prng;

/// Random paper-compatible raw-conv spec (no relu/pool: the backend
/// contract is the raw accumulator output).
fn arb_spec(rng: &mut Prng) -> LayerSpec {
    let c = *rng.choose(&[1usize, 2, 3, 4, 5, 8, 12, 16]);
    let k = *rng.choose(&[4usize, 8, 12, 16]);
    let h = 3 + rng.below(10) as usize;
    let w = 3 + rng.below(10) as usize;
    LayerSpec::new(c, h, w, k)
}

fn arb_case(rng: &mut Prng, spec: &LayerSpec) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
    (
        Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        ),
        Tensor::from_vec(
            &[spec.k, spec.c, 3, 3],
            rng.bytes_below(spec.k * spec.c * 9, 256),
        ),
        (0..spec.k).map(|_| rng.range_i64(-100, 100) as i32).collect(),
    )
}

fn run_both(
    kind: JobKind,
    spec: &LayerSpec,
    img: &Tensor<u8>,
    weights: &Tensor<u8>,
    bias: &[i32],
) -> (Tensor<i32>, Tensor<i32>) {
    let payload = JobPayload {
        kind,
        spec,
        img,
        weights,
        bias,
        weights_resident: false,
    };
    let sim = SimBackend::new(IpCoreConfig::default())
        .run(&payload)
        .unwrap_or_else(|e| panic!("sim backend {spec:?} {kind:?}: {e}"));
    let gold = GoldenBackend::new()
        .run(&payload)
        .unwrap_or_else(|e| panic!("golden backend {spec:?} {kind:?}: {e}"));
    (sim.output, gold.output)
}

#[test]
fn prop_standard_jobs_agree_across_backends() {
    for seed in 0..50u64 {
        let mut rng = Prng::new(seed);
        let spec = arb_spec(&mut rng);
        let (img, wts, bias) = arb_case(&mut rng, &spec);
        let (sim, gold) = run_both(JobKind::Standard, &spec, &img, &wts, &bias);
        assert_eq!(sim.data(), gold.data(), "seed {seed} spec {spec:?}");
    }
}

#[test]
fn prop_depthwise_jobs_agree_across_backends() {
    for seed in 100..140u64 {
        let mut rng = Prng::new(seed);
        let c = *rng.choose(&[1usize, 3, 4, 8, 16]);
        let h = 3 + rng.below(10) as usize;
        let w = 3 + rng.below(10) as usize;
        let spec = LayerSpec::new(c, h, w, c);
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let wts = Tensor::from_vec(&[c, 3, 3], rng.bytes_below(c * 9, 256));
        let bias: Vec<i32> = (0..c).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let (sim, gold) = run_both(JobKind::Depthwise, &spec, &img, &wts, &bias);
        assert_eq!(sim.data(), gold.data(), "seed {seed} c={c} h={h} w={w}");
    }
}

#[test]
fn prop_pointwise_as_3x3_jobs_agree_across_backends_and_reference() {
    for seed in 200..230u64 {
        let mut rng = Prng::new(seed);
        let c = *rng.choose(&[2usize, 4, 8]);
        let k = *rng.choose(&[4usize, 8]);
        let h = 3 + rng.below(8) as usize;
        let w = 3 + rng.below(8) as usize;
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let w1x1 = Tensor::from_vec(&[k, c], rng.bytes_below(k * c, 256));
        let bias: Vec<i32> = (0..k).map(|_| rng.range_i64(-50, 50) as i32).collect();

        // Lower 1x1 -> padded 3x3, the IP core's dataflow.
        let padded = pad1(&img);
        let w3 = pointwise_as_3x3(&w1x1);
        let spec = LayerSpec::new(c, h + 2, w + 2, k);

        let (sim, gold) = run_both(JobKind::PointwiseAs3x3, &spec, &padded, &w3, &bias);
        let want = golden_pointwise(&img, &w1x1, &bias);
        assert_eq!(sim.data(), want.data(), "seed {seed}: sim vs direct 1x1");
        assert_eq!(gold.data(), want.data(), "seed {seed}: golden vs direct 1x1");
    }
}

#[test]
fn xla_backend_agrees_when_available() {
    let mut xla = match XlaBackend::try_new() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping xla parity leg (feature off or artifacts missing): {e}");
            return;
        }
    };
    let specs = xla.served_specs();
    assert!(!specs.is_empty(), "linked runtime must serve raw-conv specs");
    for (i, spec) in specs.iter().enumerate() {
        if spec.h > 64 {
            continue; // S52-sized shapes have their own test elsewhere
        }
        let mut rng = Prng::new(3000 + i as u64);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 128),
        );
        let wts = Tensor::from_vec(
            &[spec.k, spec.c, 3, 3],
            rng.bytes_below(spec.k * spec.c * 9, 32),
        );
        let bias: Vec<i32> = (0..spec.k).map(|_| rng.range_i64(-20, 20) as i32).collect();
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
        };
        let from_xla = xla.run(&payload).unwrap();
        let (sim, gold) = run_both(JobKind::Standard, spec, &img, &wts, &bias);
        assert_eq!(sim.data(), gold.data(), "{}", spec.name());
        assert_eq!(from_xla.output.data(), gold.data(), "{}: xla vs golden", spec.name());
    }
}

#[test]
fn capability_masks_are_honest() {
    // A backend that claims a kind must run it; one that declines must
    // refuse at run() too (so routing bugs fail loudly, not wrongly).
    use repro::hw::AccumMode;
    let spec = LayerSpec::new(4, 6, 6, 4);
    let img = Tensor::<u8>::zeros(&[4, 6, 6]);
    let dw_wts = Tensor::<u8>::zeros(&[4, 3, 3]);
    let bias = vec![0i32; 4];
    let payload = JobPayload {
        kind: JobKind::Depthwise,
        spec: &spec,
        img: &img,
        weights: &dw_wts,
        bias: &bias,
        weights_resident: false,
    };

    let mut capable = SimBackend::new(IpCoreConfig::default());
    assert!(capable.capability().supports(JobKind::Depthwise));
    assert!(capable.run(&payload).is_ok());

    let mut incapable = SimBackend::new(IpCoreConfig {
        mode: AccumMode::Wrap8,
        ..Default::default()
    });
    assert!(!incapable.capability().supports(JobKind::Depthwise));
    assert!(incapable.run(&payload).is_err());
}
