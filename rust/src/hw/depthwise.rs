//! Depthwise convolution on the paper's IP core — the MobileNet case.
//!
//! §4.1 motivates the BRAM layout with MobileNet, but MobileNet's
//! backbone is depthwise-separable: a per-channel 3×3 (depthwise)
//! followed by a 1×1 across channels (pointwise). Neither matches the
//! core's dataflow, and this module quantifies exactly how well the
//! fixed-function architecture degrades:
//!
//! * **depthwise** — no cross-channel accumulation, so the four PCOREs
//!   of a computing core (which share one image-window broadcast) can
//!   serve only ONE channel per 8-cycle step: 25 % PCORE utilisation.
//!   Each core still covers its channel quarter in parallel, so a
//!   depthwise layer costs `ceil(C/4) × windows × 8` cycles.
//! * **pointwise (1×1)** — runs as a zero-padded 3×3 (weights placed at
//!   the centre tap): functionally exact, but 8 of 9 MACs multiply by
//!   zero — 11 % MAC utilisation. [`pointwise_as_3x3`] builds the
//!   padded weights; the cycle cost is the standard path's.
//!
//! The honest conclusion (EXPERIMENTS.md ABL): the paper's core runs
//! MobileNet-style blocks at 9–25 % effective utilisation; a deployable
//! revision needs a per-PCORE window path or a dedicated 1×1 mode.

use super::ip_core::{CycleStats, IpCore};
use super::AccumMode;
use crate::model::Tensor;
use crate::paper::{CYCLES_PER_PSUM_GROUP, KH, KW, N_CORES};

/// Golden depthwise 3×3: `out[c] = img[c] ⊛ w[c] + bias[c]`.
pub fn golden_depthwise3x3(
    img: &Tensor<u8>,
    w: &Tensor<u8>,
    bias: &[i32],
    relu: bool,
) -> Tensor<i32> {
    let (c, h, width) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    assert_eq!(w.shape(), &[c, KH, KW], "depthwise weights are (C,3,3)");
    assert_eq!(bias.len(), c);
    let (oh, ow) = (h - KH + 1, width - KW + 1);
    let mut out = Tensor::<i32>::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for y in 0..oh {
            for x in 0..ow {
                let mut acc = bias[ci];
                for dy in 0..KH {
                    for dx in 0..KW {
                        acc += img.at3(ci, y + dy, x + dx) as i32
                            * w.data()[(ci * KH + dy) * KW + dx] as i32;
                    }
                }
                if relu && acc < 0 {
                    acc = 0;
                }
                out.set3(ci, y, x, acc);
            }
        }
    }
    out
}

/// Result of a depthwise run on the simulated core.
#[derive(Debug)]
pub struct DepthwiseRun {
    pub output: Tensor<i32>,
    pub cycles: CycleStats,
    /// Fraction of PCORE-issue slots that did useful work (≤ 0.25).
    pub pcore_utilisation: f64,
}

impl IpCore {
    /// Depthwise 3×3 on the IP core: each computing core walks its
    /// channel quarter one channel per sweep (one active PCORE).
    pub fn run_depthwise(
        &mut self,
        img: &Tensor<u8>,
        weights: &Tensor<u8>,
        bias: &[i32],
        relu: bool,
    ) -> anyhow::Result<DepthwiseRun> {
        anyhow::ensure!(
            self.config.mode == AccumMode::I32,
            "depthwise runs in production (I32) mode"
        );
        let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
        anyhow::ensure!(weights.shape() == [c, KH, KW], "weights (C,3,3)");
        anyhow::ensure!(bias.len() == c, "bias (C,)");
        anyhow::ensure!(h >= KH && w >= KW, "image at least 3x3");

        let output = golden_depthwise3x3(img, weights, bias, relu);
        let (oh, ow) = (h - KH + 1, w - KW + 1);
        let windows = (oh * ow) as u64;

        // The slowest core owns ceil(C/4) channels; one 8-cycle step per
        // window per channel, single active PCORE.
        let rounds = c.div_ceil(N_CORES) as u64;
        let compute = rounds * windows * CYCLES_PER_PSUM_GROUP;
        let in_bytes = (img.len() + weights.len() + 4 * bias.len()) as u64;
        let dma_in = self.dma.transfer(in_bytes);
        let dma_out = self.dma.transfer((output.len() * 4) as u64);
        let mut total = compute + 5;
        if self.config.count_dma {
            total += dma_in + dma_out;
        }
        // Useful MACs / issued MAC slots: 1 of 4 PCOREs active.
        let pcore_utilisation = 0.25;

        Ok(DepthwiseRun {
            output,
            cycles: CycleStats {
                compute,
                load_visible: 5,
                load_hidden: rounds * (oh as u64 * (5 + (ow as u64 - 1) * 2)),
                dma_in,
                dma_out,
                total,
            },
            pcore_utilisation,
        })
    }
}

/// Express a 1×1 (pointwise) conv as the core's 3×3: weights at the
/// centre tap, zeros elsewhere. Exact, at 1/9 MAC utilisation — but the
/// 3×3 valid conv trims the border, so the caller must zero-pad the
/// image by 1 first ([`pad1`]).
pub fn pointwise_as_3x3(w1x1: &Tensor<u8>) -> Tensor<u8> {
    let (k, c) = (w1x1.shape()[0], w1x1.shape()[1]);
    let mut out = Tensor::<u8>::zeros(&[k, c, KH, KW]);
    for ki in 0..k {
        for ci in 0..c {
            let v = w1x1.data()[ki * c + ci];
            let idx = out.idx4(ki, ci, 1, 1); // centre tap
            out.data_mut()[idx] = v;
        }
    }
    out
}

/// Zero-pad an image by one pixel on every side.
pub fn pad1(img: &Tensor<u8>) -> Tensor<u8> {
    let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let mut out = Tensor::<u8>::zeros(&[c, h + 2, w + 2]);
    for ci in 0..c {
        for y in 0..h {
            for x in 0..w {
                let v = img.at3(ci, y, x);
                out.set3(ci, y + 1, x + 1, v);
            }
        }
    }
    out
}

/// Golden pointwise (1×1) conv for the parity tests.
pub fn golden_pointwise(img: &Tensor<u8>, w1x1: &Tensor<u8>, bias: &[i32]) -> Tensor<i32> {
    let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let k = w1x1.shape()[0];
    let mut out = Tensor::<i32>::zeros(&[k, h, w]);
    for ki in 0..k {
        for y in 0..h {
            for x in 0..w {
                let mut acc = bias[ki];
                for ci in 0..c {
                    acc += img.at3(ci, y, x) as i32 * w1x1.data()[ki * c + ci] as i32;
                }
                out.set3(ki, y, x, acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::IpCoreConfig;
    use crate::model::LayerSpec;
    use crate::util::prng::Prng;

    fn dw_case(c: usize, h: usize, w: usize, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        (
            Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256)),
            Tensor::from_vec(&[c, 3, 3], rng.bytes_below(c * 9, 256)),
            (0..c).map(|_| rng.range_i64(-20, 20) as i32).collect(),
        )
    }

    #[test]
    fn depthwise_matches_golden_and_cycle_model() {
        let (img, wts, bias) = dw_case(8, 10, 10, 61);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_depthwise(&img, &wts, &bias, false).unwrap();
        assert_eq!(
            run.output.data(),
            golden_depthwise3x3(&img, &wts, &bias, false).data()
        );
        // 8 channels over 4 cores = 2 rounds x 64 windows x 8 cycles.
        assert_eq!(run.cycles.compute, 2 * 64 * 8);
        assert!((run.pcore_utilisation - 0.25).abs() < 1e-12);
    }

    #[test]
    fn depthwise_is_4x_less_efficient_than_standard_per_mac() {
        // Same MAC count, standard vs depthwise: depthwise pays 4x cycles.
        let (img, wts, bias) = dw_case(8, 10, 10, 62);
        let mut core = IpCore::new(IpCoreConfig::default());
        let dw = core.run_depthwise(&img, &wts, &bias, false).unwrap();
        let dw_macs = (8 * 8 * 8 * 9) as f64;
        let dw_macs_per_cycle = dw_macs / dw.cycles.compute as f64;
        // Standard conv: 2 PSUMs/cycle x 9 MACs = 18 MACs/cycle.
        assert!((dw_macs_per_cycle - 18.0 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn pointwise_via_padded_3x3_is_exact() {
        let mut rng = Prng::new(63);
        let (c, h, w, k) = (8, 6, 7, 8);
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let w1 = Tensor::from_vec(&[k, c], rng.bytes_below(k * c, 256));
        let bias: Vec<i32> = (0..k).map(|_| rng.range_i64(-10, 10) as i32).collect();

        let want = golden_pointwise(&img, &w1, &bias);

        let padded = pad1(&img);
        let w3 = pointwise_as_3x3(&w1);
        let spec = LayerSpec::new(c, h + 2, w + 2, k);
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_layer(&spec, &padded, &w3, &bias, None).unwrap();
        assert_eq!(run.output.as_i32().data(), want.data());
    }

    #[test]
    fn depthwise_relu_clamps() {
        let (img, wts, _) = dw_case(4, 5, 5, 64);
        let bias = vec![-1_000_000; 4];
        let mut core = IpCore::new(IpCoreConfig::default());
        let run = core.run_depthwise(&img, &wts, &bias, true).unwrap();
        assert!(run.output.data().iter().all(|&v| v >= 0));
    }

    #[test]
    fn depthwise_rejects_wrap8() {
        let (img, wts, bias) = dw_case(4, 5, 5, 65);
        let mut core = IpCore::new(IpCoreConfig {
            mode: AccumMode::Wrap8,
            ..Default::default()
        });
        assert!(core.run_depthwise(&img, &wts, &bias, false).is_err());
    }
}
