//! Bench: experiment T1 — regenerates the paper's Table 1 from the
//! resource model and times the model itself (it sits on the serving
//! path when the coordinator plans deployments).

use repro::bench_util::{black_box, Bencher};
use repro::hw::device::TABLE1_DEVICES;
use repro::hw::resource::{estimate, max_cores, render_table1, PAPER_TABLE1};

fn main() {
    println!("=== bench: table1 (experiment T1) ===");
    print!("{}", render_table1());
    println!("paper:");
    for r in PAPER_TABLE1 {
        println!(
            "{:<22} {:>7}          {:>7}          {:>6.0} MHz",
            r.device, r.luts, r.ffs, r.fmax_mhz
        );
    }
    for d in TABLE1_DEVICES {
        let m = max_cores(&d);
        println!(
            "max IP cores on {:<22} by_lut={:<3} by_ff={:<3} binding={}",
            d.name, m.by_lut, m.by_ff, m.binding
        );
    }

    let b = Bencher::quick();
    b.run("estimate(xc7z020clg400)", || {
        black_box(estimate(&TABLE1_DEVICES[0]))
    });
    b.run("render_table1", || black_box(render_table1()));
}
