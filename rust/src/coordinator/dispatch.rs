//! Worker pool over heterogeneous [`ConvBackend`]s, fed closed batches.
//!
//! The paper's deployment is N replicated IP cores ("up to 20
//! concurrently", §5.1); a production pool mixes those with host
//! fallback workers and, when linked, an XLA path. Each worker thread
//! owns one `Box<dyn ConvBackend>`; dispatch is:
//!
//! 1. **capability-masked** — a batch of depthwise jobs is only offered
//!    to workers whose backend supports depthwise (wrap-8 cores and the
//!    XLA path decline them);
//! 2. **health-aware** — workers whose backend exposes a
//!    [`WorkerHealth`] flag (remote peers with a probe thread) are
//!    skipped while unhealthy, as long as a healthy capable sibling
//!    exists. Health degrades capacity, never correctness: a pool whose
//!    capable workers are all unhealthy still routes to them;
//! 3. **cost-weighted least-loaded** — queue depth is measured in each
//!    backend's own [`CostModel`] units (closed-form cycles for IP
//!    cores, modelled MACs for host fallback), so a big S52 layer
//!    counts for more than an edge-CNN layer and slow fallback workers
//!    fill only after the accelerators queue up.
//!
//! **Failover:** when a backend fails a job (a dropped remote peer, a
//! wedged device), the worker releases its queue charge and re-enqueues
//! the job on the least-loaded capable sibling it has not tried yet —
//! up to [`MAX_DISPATCH_ATTEMPTS`] workers total. Only when attempts
//! are exhausted, or no untried capable worker exists, does the pool
//! answer an error result. A flapping machine therefore degrades
//! capacity instead of erroring user requests.

use super::batcher::Batch;
use super::metrics::Metrics;
use super::request::{ConvResult, Submission};
use crate::backend::{
    Capability, ConvBackend, CostModel, JobKind, KnownWeights, SimBackend, WorkerHealth,
};
use crate::hw::{AccumMode, IpCoreConfig};
use crate::model::LayerSpec;
use crate::telemetry::scrape::{
    render_counters, render_stage_histogram, render_worker_gauges, ScrapeSource,
};
use crate::telemetry::{SpanSink, Stage};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Upper bound on how many workers one job may be offered before the
/// pool gives up and answers an error result: the initial dispatch plus
/// up to two failover hops.
pub const MAX_DISPATCH_ATTEMPTS: usize = 3;

enum WorkerMsg {
    /// A closed batch, plus the indices of workers that already failed
    /// these jobs (empty on first dispatch) — failover excludes them.
    Run(Batch, Vec<usize>),
    Shutdown,
}

struct WorkerEntry {
    tx: Sender<WorkerMsg>,
    /// Outstanding modelled work (backend cost units), for least-loaded
    /// dispatch. Plain atomic — the whole table is shared via one Arc.
    load: AtomicI64,
    /// Capability snapshot taken before the backend moved into its
    /// thread; drives the dispatch mask.
    capability: Capability,
    /// Cost model snapshot; weighs this worker's queue.
    cost: CostModel,
    name: &'static str,
    /// Liveness flag for backends that can flap (remote peers); `None`
    /// means always healthy.
    health: Option<Arc<WorkerHealth>>,
    /// Weight-store residency belief, for wire-v4 remote workers;
    /// `None` means no weight cache on this path. Dispatch snapshots it
    /// per job so the wire weight term is discounted when the peer
    /// already holds the blob.
    known: Option<Arc<KnownWeights>>,
    /// Interned span tag of this worker's name (0 when tracing is off)
    /// — per-span worker attribution is a plain integer store.
    tag: u64,
}

impl WorkerEntry {
    fn is_healthy(&self) -> bool {
        self.health.as_ref().map_or(true, |h| h.is_healthy())
    }
}

/// The routing table the pool front shares with every worker thread.
/// Failover needs workers to re-enqueue failed jobs on siblings, so
/// selection and load accounting live here rather than on [`CorePool`].
struct WorkerTable {
    entries: Vec<WorkerEntry>,
    metrics: Arc<Metrics>,
    /// Shared span sink; `None` disables the span path entirely (the
    /// per-stage histograms still record — they are counters, not
    /// traces).
    trace: Option<Arc<SpanSink>>,
}

impl WorkerTable {
    /// Least-loaded capable worker outside `exclude`. Unhealthy workers
    /// are skipped while any healthy capable candidate remains; when
    /// every capable candidate is unhealthy the pick falls back to them
    /// (failover covers the jobs that then fail), so health can never
    /// make a routable batch unroutable.
    fn pick(
        &self,
        spec: &LayerSpec,
        kind: JobKind,
        accum: AccumMode,
        exclude: &[usize],
    ) -> Option<usize> {
        let candidate = |require_healthy: bool| {
            self.entries
                .iter()
                .enumerate()
                .filter(|(i, e)| {
                    !exclude.contains(i)
                        && (!require_healthy || e.is_healthy())
                        && e.capability.allows(spec, kind, accum)
                })
                .min_by_key(|(_, e)| e.load.load(Ordering::Relaxed))
                .map(|(i, _)| i)
        };
        candidate(true).or_else(|| candidate(false))
    }

    /// Charge worker `idx`'s queue for every job in `batch` and send it.
    /// Hands the batch back (charge undone) if the worker already shut
    /// down — only possible when a failover hop races pool teardown.
    ///
    /// Each job's weight-residency flag is snapshotted *here*, against
    /// the chosen worker's [`KnownWeights`], and stored on the job —
    /// charge and release both read that snapshot, so the accounting
    /// stays symmetric even if residency changes while the job is in
    /// flight (and failover hops re-snapshot against the new worker).
    fn send_batch(&self, idx: usize, mut batch: Batch, tried: Vec<usize>) -> Result<(), Batch> {
        let entry = &self.entries[idx];
        for s in &mut batch.jobs {
            s.job.wire_weights_cached = entry
                .known
                .as_ref()
                .is_some_and(|k| k.contains(s.job.weights_hash));
        }
        let total: i64 = batch
            .jobs
            .iter()
            .map(|s| {
                entry
                    .cost
                    .cost_cached(&s.job.spec, s.job.kind, s.job.wire_weights_cached)
                    as i64
            })
            .sum();
        entry.load.fetch_add(total, Ordering::Relaxed);
        match entry.tx.send(WorkerMsg::Run(batch, tried)) {
            Ok(()) => Ok(()),
            Err(rejected) => {
                entry.load.fetch_sub(total, Ordering::Relaxed);
                match rejected.0 {
                    WorkerMsg::Run(batch, _) => Err(batch),
                    WorkerMsg::Shutdown => unreachable!("we sent Run"),
                }
            }
        }
    }

    /// Failover hop: re-enqueue one failed submission on the
    /// least-loaded capable worker not yet tried. Hands the submission
    /// back when no such worker exists (or the target shut down first).
    fn redispatch(&self, sub: Submission, tried: &[usize]) -> Result<(), Submission> {
        let Some(idx) = self.pick(&sub.job.spec, sub.job.kind, sub.job.accum, tried) else {
            return Err(sub);
        };
        let batch = Batch {
            spec: sub.job.spec,
            weights_id: sub.job.weights_id,
            kind: sub.job.kind,
            accum: sub.job.accum,
            jobs: vec![sub],
        };
        self.send_batch(idx, batch, tried.to_vec())
            .map_err(|mut batch| batch.jobs.pop().expect("the one submission we packed"))
    }

    /// Terminal failure: attempts exhausted or no sibling to try.
    fn fail(&self, core_idx: usize, name: &'static str, sub: Submission, err: &str) {
        self.metrics.record_failure();
        // Receiver may have hung up (fire-and-forget); fine.
        let _ = sub.reply.send(ConvResult {
            id: sub.job.id,
            spec: sub.job.spec,
            kind: sub.job.kind,
            output: crate::model::Tensor::zeros(&[0]),
            cycles: Default::default(),
            core: core_idx,
            backend: name,
            latency: sub.enqueued.elapsed(),
            weights_reused: false,
            error: Some(err.to_string()),
            queue_us: 0,
            compute_us: 0,
        });
    }
}

/// Run one batch on this worker's backend, failing individual jobs over
/// to siblings via the shared table when the backend errors.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    backend: &mut dyn ConvBackend,
    resident_weights: &mut Option<u64>,
    table: &WorkerTable,
    core_idx: usize,
    name: &'static str,
    cost: CostModel,
    batch: Batch,
    tried: Vec<usize>,
) {
    // Weight-stationary across the batch: the batcher closed these jobs
    // over one weight set, so the first job pays the weight DMA (unless
    // the set is already resident from the previous batch) and the rest
    // reuse it. The flags are positional — computed up front — so the
    // whole batch can go through the backend's batch entry point in ONE
    // call: pipelining backends (remote peers) put every job on the
    // wire before the first reply returns, instead of paying a full
    // round trip per job.
    //
    // The positional flags are *optimistic*: reporting is failure-aware.
    // A job only *reports* `weights_reused=true` (and counts a skipped
    // DMA in metrics) when the reuse actually happened — the weights
    // were resident when the batch started, or an earlier job in this
    // batch succeeded on this worker and therefore loaded them. If job
    // 0 fails, later successes are re-reported honestly, and residency
    // is NOT recorded for the next batch (nobody paid the load), so the
    // undercharged DMA is recovered on the following batch. A failover
    // hop re-enters [`WorkerTable::redispatch`] as position 0 of a
    // fresh single-job batch, so the rescue worker recomputes the flag
    // against its *own* residency — a hop can never inherit a reuse
    // discount from the worker that failed it.
    let batch_weights = batch.weights_id;
    let resident_at_start = *resident_weights == Some(batch_weights);
    let reused_flags: Vec<bool> = (0..batch.jobs.len())
        .map(|i| i > 0 || resident_at_start)
        .collect();
    let payloads: Vec<_> = batch
        .jobs
        .iter()
        .zip(&reused_flags)
        .map(|(sub, &reused)| sub.job.payload(reused))
        .collect();
    let t0 = Instant::now();
    let runs = backend.run_batch(&payloads);
    let t1 = Instant::now();
    debug_assert_eq!(runs.len(), batch.jobs.len(), "one result per job");
    drop(payloads);
    drop(reused_flags);
    let mut any_success = false;
    let mut first_job_succeeded = false;
    for (i, (sub, run)) in batch.jobs.into_iter().zip(runs).enumerate() {
        let run = match run {
            Ok(run) => run,
            Err(e) => {
                // Release this queue's charge, then fail over: offer
                // the job to the next-cheapest capable sibling not yet
                // tried. Only when attempts are exhausted — or no such
                // sibling exists — does the pool answer an error
                // result.
                table.entries[core_idx].load.fetch_sub(
                    cost.cost_cached(&sub.job.spec, sub.job.kind, sub.job.wire_weights_cached)
                        as i64,
                    Ordering::Relaxed,
                );
                let mut tried_now = tried.clone();
                tried_now.push(core_idx);
                let give_up = if tried_now.len() < MAX_DISPATCH_ATTEMPTS {
                    match table.redispatch(sub, &tried_now) {
                        Ok(()) => {
                            table.metrics.record_retry();
                            None
                        }
                        Err(sub) => Some(sub),
                    }
                } else {
                    Some(sub)
                };
                if let Some(sub) = give_up {
                    table.fail(core_idx, name, sub, &e.to_string());
                }
                continue;
            }
        };
        // Effective (failure-aware) reuse: an earlier success in this
        // batch loaded the weights, or they were resident already.
        let reused = resident_at_start || any_success;
        any_success = true;
        if i == 0 {
            first_job_succeeded = true;
        }

        let latency = sub.enqueued.elapsed();
        table.metrics.record_completion(
            sub.job.psums(),
            run.cycles.total.max(run.cycles.compute),
            latency,
            reused,
        );
        table.entries[core_idx].load.fetch_sub(
            cost.cost_cached(&sub.job.spec, sub.job.kind, sub.job.wire_weights_cached) as i64,
            Ordering::Relaxed,
        );
        // Stage decomposition: queue is enqueue → batch pickup, compute
        // is the peer-reported figure on traced remote hops and the
        // (batch-granular) backend-call duration otherwise.
        let queue_us = t0.saturating_duration_since(sub.enqueued).as_micros() as u64;
        let hop_us = t1.saturating_duration_since(t0).as_micros() as u64;
        let (compute_us, wire_split) = match run.wire {
            Some(w) => (w.peer_compute_us, Some(w)),
            None => (hop_us, None),
        };
        let stages = &table.metrics.stages;
        stages.queue.record_us(queue_us);
        stages.compute.record_us(compute_us);
        if let Some(w) = &wire_split {
            stages.wire.record_us(w.wire_us());
        }
        if let Some(sink) = &table.trace {
            let tid = sub.job.trace.id;
            if tid != 0 {
                let tag = table.entries[core_idx].tag;
                let enq = sink.offset_us(sub.enqueued);
                let t0_us = sink.offset_us(t0);
                let t1_us = sink.offset_us(t1);
                // Queue span from the *original* enqueue: on a failover
                // hop this absorbs the failed attempts' time, keeping
                // the request tree gap-free.
                sink.record(tid, Stage::Queue, 0, enq, t0_us.saturating_sub(enq));
                sink.record(tid, Stage::Dispatch, tag, t0_us, t1_us.saturating_sub(t0_us));
                match &wire_split {
                    Some(w) => {
                        sink.record(tid, Stage::Wire, tag, t0_us, w.wire_us());
                        sink.record(
                            tid,
                            Stage::Compute,
                            tag,
                            t1_us.saturating_sub(w.peer_compute_us),
                            w.peer_compute_us,
                        );
                    }
                    None => {
                        sink.record(tid, Stage::Compute, tag, t0_us, t1_us.saturating_sub(t0_us));
                    }
                }
                // Non-stream jobs: this hop completes the request, so
                // the dispatcher owns the root. Admission + queue +
                // dispatch tile it exactly. Stream jobs leave the root
                // to the stream driver (one root per image, not per
                // layer hop).
                if sub.job.trace.layer.is_none() {
                    let root_start = enq.saturating_sub(sub.job.trace.admission_us);
                    sink.record(
                        tid,
                        Stage::Admission,
                        0,
                        root_start,
                        sub.job.trace.admission_us,
                    );
                    sink.record(
                        tid,
                        Stage::Request,
                        0,
                        root_start,
                        t1_us.saturating_sub(root_start),
                    );
                }
            }
        }
        // Receiver may have hung up (fire-and-forget); fine.
        let _ = sub.reply.send(ConvResult {
            id: sub.job.id,
            spec: sub.job.spec,
            kind: sub.job.kind,
            output: run.output,
            cycles: run.cycles,
            core: core_idx,
            backend: name,
            latency,
            weights_reused: reused,
            error: None,
            queue_us,
            compute_us,
        });
    }
    if any_success {
        // Residency carries to the next batch only when the load was
        // actually paid (resident already, or job 0 ran cold and
        // succeeded). If job 0 failed, later jobs ran on optimistic
        // discounted payloads — clearing residency makes the next batch
        // of these weights pay the DMA instead of compounding the
        // undercharge.
        *resident_weights = if resident_at_start || first_job_succeeded {
            Some(batch_weights)
        } else {
            None
        };
    }
}

/// Pool of conv-backend workers (simulated IP cores by default).
pub struct CorePool {
    table: Arc<WorkerTable>,
    handles: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    config: IpCoreConfig,
}

impl CorePool {
    /// Homogeneous pool: `n_cores` simulated IP cores (the paper's
    /// deployment).
    pub fn new(n_cores: usize, config: IpCoreConfig) -> Self {
        let backends = (0..n_cores)
            .map(|_| Box::new(SimBackend::new(config)) as Box<dyn ConvBackend>)
            .collect();
        Self::with_backends(backends, config)
    }

    /// Heterogeneous pool: one worker per backend, in order. `config`
    /// stays around for frequency-based reporting (simulated µs on the
    /// wire protocol).
    pub fn with_backends(backends: Vec<Box<dyn ConvBackend>>, config: IpCoreConfig) -> Self {
        Self::with_backends_traced(backends, config, None)
    }

    /// [`Self::with_backends`] with an optional shared span sink: when
    /// `Some`, every dispatch hop records worker-tagged spans into it.
    pub fn with_backends_traced(
        backends: Vec<Box<dyn ConvBackend>>,
        config: IpCoreConfig,
        trace: Option<Arc<SpanSink>>,
    ) -> Self {
        assert!(!backends.is_empty(), "pool needs at least one backend");
        let metrics = Arc::new(Metrics::new());
        // Build the full routing table before any worker starts:
        // failover needs every worker to see every sibling's entry.
        let mut receivers = Vec::with_capacity(backends.len());
        let entries = backends
            .iter()
            .map(|b| {
                let (tx, rx) = channel::<WorkerMsg>();
                receivers.push(rx);
                WorkerEntry {
                    tx,
                    load: AtomicI64::new(0),
                    capability: b.capability(),
                    cost: b.cost_model(),
                    name: b.name(),
                    health: b.health(),
                    known: b.known_weights(),
                    tag: trace.as_ref().map_or(0, |s| s.worker_tag(b.name())),
                }
            })
            .collect();
        let table = Arc::new(WorkerTable {
            entries,
            metrics: Arc::clone(&metrics),
            trace,
        });
        let handles = backends
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(idx, (backend, rx))| Self::spawn_worker(idx, backend, rx, Arc::clone(&table)))
            .collect();
        CorePool {
            table,
            handles,
            metrics,
            config,
        }
    }

    pub fn n_cores(&self) -> usize {
        self.table.entries.len()
    }

    pub fn ip_config(&self) -> IpCoreConfig {
        self.config
    }

    /// `(name, capability)` per worker, in worker order.
    pub fn worker_capabilities(&self) -> Vec<(&'static str, Capability)> {
        self.table
            .entries
            .iter()
            .map(|w| (w.name, w.capability.clone()))
            .collect()
    }

    /// Cost model per worker, in worker order (the wire protocol's
    /// `hello` frame quotes these to remote coordinators).
    pub fn worker_cost_models(&self) -> Vec<CostModel> {
        self.table.entries.iter().map(|w| w.cost).collect()
    }

    /// Outstanding queued work per worker, in each worker's own
    /// cost-model units (the quantity least-loaded dispatch compares).
    /// Observability + tests; values drop as workers complete jobs.
    pub fn worker_loads(&self) -> Vec<i64> {
        self.table
            .entries
            .iter()
            .map(|w| w.load.load(Ordering::Relaxed))
            .collect()
    }

    /// Liveness per worker, in worker order. Workers without a health
    /// flag (local backends) always read healthy.
    pub fn worker_health(&self) -> Vec<bool> {
        self.table.entries.iter().map(|w| w.is_healthy()).collect()
    }

    /// Unhealthy→healthy transitions summed over every worker that
    /// exposes a health flag — "how many times did a peer come back".
    pub fn recovered_peers(&self) -> u64 {
        self.table
            .entries
            .iter()
            .filter_map(|w| w.health.as_ref())
            .map(|h| h.recoveries())
            .sum()
    }

    /// The span sink this pool records into (`None` when tracing is
    /// off).
    pub fn span_sink(&self) -> Option<Arc<SpanSink>> {
        self.table.trace.as_ref().map(Arc::clone)
    }

    /// A read-only Prometheus view over this pool's live state —
    /// counters, stage-keyed latency histograms and per-worker gauges —
    /// for [`crate::telemetry::scrape::ScrapeServer::attach`].
    pub fn scrape_source(&self) -> Arc<dyn ScrapeSource> {
        Arc::new(PoolScrape {
            table: Arc::clone(&self.table),
        })
    }

    /// Client-side weight-cache accounting summed over every wire-v4
    /// remote worker: `(hits, misses, wire_weight_bytes_saved)`. Flows
    /// into the serving report.
    pub fn weight_cache_stats(&self) -> (u64, u64, u64) {
        self.table
            .entries
            .iter()
            .filter_map(|w| w.known.as_ref())
            .map(|k| k.stats())
            .fold((0, 0, 0), |acc, s| (acc.0 + s.0, acc.1 + s.1, acc.2 + s.2))
    }

    fn spawn_worker(
        core_idx: usize,
        backend: Box<dyn ConvBackend>,
        rx: Receiver<WorkerMsg>,
        table: Arc<WorkerTable>,
    ) -> JoinHandle<()> {
        let name = backend.name();
        let cost = backend.cost_model();
        std::thread::Builder::new()
            .name(format!("conv-{name}-{core_idx}"))
            .spawn(move || {
                let mut backend = backend;
                let mut resident_weights: Option<u64> = None;
                loop {
                    match rx.recv() {
                        Ok(WorkerMsg::Run(batch, tried)) => run_batch(
                            &mut *backend,
                            &mut resident_weights,
                            &table,
                            core_idx,
                            name,
                            cost,
                            batch,
                            tried,
                        ),
                        Ok(WorkerMsg::Shutdown) | Err(_) => break,
                    }
                }
                // Failover hops from still-draining siblings can land
                // behind the Shutdown marker: serve them instead of
                // dropping their replies.
                while let Ok(WorkerMsg::Run(batch, tried)) = rx.try_recv() {
                    run_batch(
                        &mut *backend,
                        &mut resident_weights,
                        &table,
                        core_idx,
                        name,
                        cost,
                        batch,
                        tried,
                    );
                }
            })
            .expect("spawn conv worker")
    }

    /// Dispatch a closed batch to the least-loaded *capable* worker
    /// (healthy ones preferred). Returns the batch untouched when no
    /// worker in the pool can serve its (spec, kind, accum) — kind
    /// mask, accumulator-mode match and any backend spec allowlist.
    pub fn try_dispatch(&self, batch: Batch) -> Result<(), Batch> {
        let Some(idx) = self
            .table
            .pick(&batch.spec, batch.kind, batch.accum, &[])
        else {
            return Err(batch);
        };
        let n_jobs = batch.jobs.len() as u64;
        self.table.send_batch(idx, batch, Vec::new())?;
        self.metrics.requests.fetch_add(n_jobs, Ordering::Relaxed);
        Ok(())
    }

    /// [`Self::try_dispatch`] that treats an unroutable batch as a
    /// deployment bug.
    pub fn dispatch(&self, batch: Batch) {
        if let Err(batch) = self.try_dispatch(batch) {
            panic!(
                "no backend in the pool supports {:?} jobs in {:?} accum mode ({} workers)",
                batch.kind,
                batch.accum,
                self.table.entries.len()
            );
        }
    }

    /// Graceful shutdown: drain queues, join threads.
    pub fn shutdown(self) {
        for e in &self.table.entries {
            let _ = e.tx.send(WorkerMsg::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Read-only Prometheus view over the worker table — what
/// [`CorePool::scrape_source`] hands the scrape endpoint. Holds the
/// table (not the pool), so scrapes keep answering while the pool
/// front is busy and stop mattering once the run ends.
struct PoolScrape {
    table: Arc<WorkerTable>,
}

impl ScrapeSource for PoolScrape {
    fn render_prometheus(&self) -> String {
        let mut out = String::new();
        render_counters(&mut out, &self.table.metrics);
        for (label, h) in self.table.metrics.stages.labelled() {
            render_stage_histogram(&mut out, &label, h);
        }
        for (i, e) in self.table.entries.iter().enumerate() {
            // Index-suffix the name: pools legally run several workers
            // of one backend type, and Prometheus series must not alias.
            let name = format!("{}-{i}", e.name);
            render_worker_gauges(
                &mut out,
                &name,
                e.load.load(Ordering::Relaxed),
                e.is_healthy(),
                e.known.as_ref().map_or(0, |k| k.len()),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendRun, GoldenBackend, Im2colBackend, JobKind, JobPayload};
    use crate::coordinator::batcher::Batch;
    use crate::coordinator::request::{ConvJob, Submission};
    use crate::hw::depthwise::golden_depthwise3x3;
    use crate::hw::AccumMode;
    use crate::model::{golden, LayerSpec, QUICKSTART};
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn batch_of(job: ConvJob, tx: &std::sync::mpsc::Sender<ConvResult>) -> Batch {
        Batch {
            spec: job.spec,
            weights_id: job.weights_id,
            kind: job.kind,
            accum: job.accum,
            jobs: vec![Submission {
                job,
                reply: tx.clone(),
                enqueued: std::time::Instant::now(),
            }],
        }
    }

    fn one_job_batch(id: u64) -> (Batch, std::sync::mpsc::Receiver<ConvResult>) {
        let (tx, rx) = channel();
        let job = ConvJob::synthetic(id, QUICKSTART, id);
        (batch_of(job, &tx), rx)
    }

    #[test]
    fn pool_computes_correct_results() {
        let pool = CorePool::new(2, IpCoreConfig::default());
        let (batch, rx) = one_job_batch(1);
        let job = ConvJob::synthetic(1, QUICKSTART, 1);
        pool.dispatch(batch);
        let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let want = golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false);
        assert_eq!(res.output.data(), want.data());
        assert_eq!(res.id, 1);
        assert_eq!(res.backend, "sim-ipcore-i32");
        pool.shutdown();
    }

    #[test]
    fn batch_reuses_weights_after_first() {
        let pool = CorePool::new(1, IpCoreConfig::default());
        let (tx, rx) = channel();
        let jobs: Vec<Submission> = (0..3)
            .map(|i| Submission {
                job: ConvJob::synthetic(i, QUICKSTART, i),
                reply: tx.clone(),
                enqueued: std::time::Instant::now(),
            })
            .collect();
        let weights_id = jobs[0].job.weights_id;
        pool.dispatch(Batch {
            spec: QUICKSTART,
            weights_id,
            kind: JobKind::Standard,
            accum: AccumMode::I32,
            jobs,
        });
        let results: Vec<ConvResult> = (0..3)
            .map(|_| rx.recv_timeout(Duration::from_secs(10)).unwrap())
            .collect();
        assert!(!results[0].weights_reused);
        assert!(results[1].weights_reused);
        assert!(results[2].weights_reused);
        pool.shutdown();
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let pool = CorePool::new(4, IpCoreConfig::default());
        let (tx, rx) = channel();
        let n = 32u64;
        for i in 0..n {
            let job = ConvJob::synthetic(i, QUICKSTART, i);
            pool.dispatch(batch_of(job, &tx));
        }
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        pool.shutdown();
    }

    #[test]
    fn metrics_accumulate() {
        let pool = CorePool::new(1, IpCoreConfig::default());
        let (batch, rx) = one_job_batch(5);
        pool.dispatch(batch);
        let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(
            pool.metrics
                .completed
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            pool.metrics.psums.load(std::sync::atomic::Ordering::Relaxed),
            QUICKSTART.psums()
        );
        pool.shutdown();
    }

    #[test]
    fn mixed_pool_answers_standard_and_depthwise() {
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(SimBackend::new(IpCoreConfig::default())),
            Box::new(GoldenBackend::new()),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        let dw_spec = LayerSpec::new(8, 10, 10, 8);
        for i in 0..6u64 {
            let job = if i % 2 == 0 {
                ConvJob::synthetic(i, QUICKSTART, i)
            } else {
                ConvJob::synthetic_depthwise(i, dw_spec, i)
            };
            pool.dispatch(batch_of(job, &tx));
        }
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 6);
        for r in &results {
            let (img, wts, bias) = match r.kind {
                JobKind::Depthwise => {
                    let j = ConvJob::synthetic_depthwise(r.id, dw_spec, r.id);
                    (j.img, j.weights, j.bias)
                }
                _ => {
                    let j = ConvJob::synthetic(r.id, QUICKSTART, r.id);
                    (j.img, j.weights, j.bias)
                }
            };
            let want = match r.kind {
                JobKind::Depthwise => golden_depthwise3x3(&img, &wts, &bias, false),
                _ => golden::conv3x3_i32(&img, &wts, &bias, false),
            };
            assert_eq!(r.output.data(), want.data(), "job {} via {}", r.id, r.backend);
        }
        pool.shutdown();
    }

    #[test]
    fn depthwise_routes_only_to_capable_backends() {
        // Worker 0 is a wrap-8 core: standard-only. All depthwise jobs
        // must land on workers 1 (i32 core) or 2 (golden fallback).
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(SimBackend::new(IpCoreConfig {
                mode: AccumMode::Wrap8,
                ..Default::default()
            })),
            Box::new(SimBackend::new(IpCoreConfig::default())),
            Box::new(GoldenBackend::new()),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        assert!(!pool.worker_capabilities()[0].1.supports(JobKind::Depthwise));
        let (tx, rx) = channel();
        let dw_spec = LayerSpec::new(8, 10, 10, 8);
        for i in 0..12u64 {
            let job = ConvJob::synthetic_depthwise(i, dw_spec, i);
            pool.dispatch(batch_of(job, &tx));
        }
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 12);
        for r in &results {
            assert_ne!(r.core, 0, "depthwise routed to the wrap8 core");
            assert_ne!(r.backend, "sim-ipcore-wrap8");
        }
        pool.shutdown();
    }

    #[test]
    fn mixed_kind_mixed_accum_burst_never_misroutes() {
        // The full routing predicate under fire: a pool mixing an I32
        // core, a wrap-8 core and a threaded im2col worker, fed a burst
        // of standard-I32, depthwise and standard-wrap8 jobs. No job may
        // land on a worker whose `Capability::allows` rejects its
        // (spec, kind, accum) triple — and every reply must be the
        // matching reference, bit for bit.
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(SimBackend::new(IpCoreConfig::default())),
            Box::new(SimBackend::new(IpCoreConfig {
                mode: AccumMode::Wrap8,
                ..Default::default()
            })),
            Box::new(Im2colBackend::new(2)),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let caps = pool.worker_capabilities();
        let (tx, rx) = channel();
        let dw_spec = LayerSpec::new(8, 10, 10, 8);
        let mut wrap8_ids = std::collections::HashSet::new();
        for i in 0..24u64 {
            let job = match i % 3 {
                0 => ConvJob::synthetic(i, QUICKSTART, i),
                1 => ConvJob::synthetic_depthwise(i, dw_spec, i),
                _ => {
                    wrap8_ids.insert(i);
                    ConvJob::synthetic(i, QUICKSTART, i).with_accum(AccumMode::Wrap8)
                }
            };
            pool.dispatch(batch_of(job, &tx));
        }
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 24);
        for r in &results {
            let accum = if wrap8_ids.contains(&r.id) {
                AccumMode::Wrap8
            } else {
                AccumMode::I32
            };
            assert!(
                caps[r.core].1.allows(&r.spec, r.kind, accum),
                "job {} ({:?}, {:?}) landed on incapable worker {} ({})",
                r.id,
                r.kind,
                accum,
                r.core,
                r.backend
            );
            // And the numerics match the per-(kind, accum) reference.
            let job = match r.kind {
                JobKind::Depthwise => ConvJob::synthetic_depthwise(r.id, dw_spec, r.id),
                _ => ConvJob::synthetic(r.id, QUICKSTART, r.id),
            };
            let want = match (r.kind, accum) {
                (JobKind::Depthwise, _) => {
                    golden_depthwise3x3(&job.img, &job.weights, &job.bias, false)
                }
                (_, AccumMode::I32) => golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false),
                (_, AccumMode::Wrap8) => {
                    let bias8: Vec<u8> = job.bias.iter().map(|&b| b as u8).collect();
                    golden::conv3x3_wrap8(&job.img, &job.weights, &bias8).map(|v| v as i32)
                }
            };
            assert_eq!(r.output.data(), want.data(), "job {} via {}", r.id, r.backend);
        }
        pool.shutdown();
    }

    #[test]
    fn wrap8_jobs_route_to_wrap8_silicon_only() {
        // The ROADMAP accum-routing gap, closed: the dispatcher matches
        // job accum requirements against Capability::accum instead of
        // relying on I32-homogeneous pools.
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(SimBackend::new(IpCoreConfig::default())),
            Box::new(SimBackend::new(IpCoreConfig {
                mode: AccumMode::Wrap8,
                ..Default::default()
            })),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        for i in 0..8u64 {
            let job = ConvJob::synthetic(i, QUICKSTART, i).with_accum(if i % 2 == 0 {
                AccumMode::I32
            } else {
                AccumMode::Wrap8
            });
            pool.dispatch(batch_of(job, &tx));
        }
        drop(tx);
        for r in rx.iter() {
            if r.id % 2 == 0 {
                assert_eq!(r.backend, "sim-ipcore-i32", "job {}", r.id);
            } else {
                assert_eq!(r.backend, "sim-ipcore-wrap8", "job {}", r.id);
            }
        }
        // An I32-only pool must hand a wrap8 batch back, not serve it wide.
        let i32_pool = CorePool::new(1, IpCoreConfig::default());
        let (tx, _rx) = channel();
        let job = ConvJob::synthetic(99, QUICKSTART, 99).with_accum(AccumMode::Wrap8);
        let back = i32_pool.try_dispatch(batch_of(job, &tx)).expect_err("must not route");
        assert_eq!(back.accum, AccumMode::Wrap8);
        pool.shutdown();
        i32_pool.shutdown();
    }

    /// Test backend that parks every job until the test releases its
    /// gate — lets a test observe queued load without racing completion.
    struct GatedBackend {
        gate: std::sync::mpsc::Receiver<()>,
        model: CostModel,
    }

    impl ConvBackend for GatedBackend {
        fn name(&self) -> &'static str {
            "gated-test"
        }
        fn capability(&self) -> Capability {
            Capability {
                standard3x3: true,
                depthwise: true,
                pointwise_as_3x3: true,
                accum: AccumMode::I32,
                paper_specs_only: false,
                spec_allowlist: None,
            }
        }
        fn cost_model(&self) -> CostModel {
            self.model
        }
        fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
            self.gate.recv().ok();
            GoldenBackend::new().run(job)
        }
    }

    #[test]
    fn least_loaded_weighs_each_queue_in_its_own_cost_units() {
        // Two parked workers with different cost models. The first job
        // lands on worker 0 (both queues empty, first wins); its queue
        // must weigh exactly worker 0's own HostMacs quote. The second
        // job must go to the now-cheaper worker 1 and weigh exactly
        // worker 1's own Im2col quote — not worker 0's units.
        let (gate_a, rx_a) = channel();
        let (gate_b, rx_b) = channel();
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(GatedBackend {
                gate: rx_a,
                model: CostModel::HostMacs,
            }),
            Box::new(GatedBackend {
                gate: rx_b,
                model: CostModel::Im2col { threads: 4 },
            }),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        pool.dispatch(batch_of(ConvJob::synthetic(0, QUICKSTART, 0), &tx));
        pool.dispatch(batch_of(ConvJob::synthetic(1, QUICKSTART, 1), &tx));
        let host = CostModel::HostMacs.cost(&QUICKSTART, JobKind::Standard) as i64;
        let im2col = CostModel::Im2col { threads: 4 }.cost(&QUICKSTART, JobKind::Standard) as i64;
        assert_ne!(host, im2col, "test premise: the two models quote different units");
        assert_eq!(pool.worker_loads(), vec![host, im2col]);
        gate_a.send(()).unwrap();
        gate_b.send(()).unwrap();
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1]);
        assert_eq!(pool.worker_loads(), vec![0, 0]);
        pool.shutdown();
    }

    #[test]
    fn im2col_only_pool_serves_standard_and_depthwise() {
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(Im2colBackend::new(2)),
            Box::new(Im2colBackend::new(2)),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        let dw_spec = LayerSpec::new(4, 8, 8, 4);
        for i in 0..6u64 {
            let job = if i % 2 == 0 {
                ConvJob::synthetic(i, QUICKSTART, i)
            } else {
                ConvJob::synthetic_depthwise(i, dw_spec, i)
            };
            pool.dispatch(batch_of(job, &tx));
        }
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.backend, "im2col-cpu");
            let job = match r.kind {
                JobKind::Depthwise => ConvJob::synthetic_depthwise(r.id, dw_spec, r.id),
                _ => ConvJob::synthetic(r.id, QUICKSTART, r.id),
            };
            let want = match r.kind {
                JobKind::Depthwise => golden_depthwise3x3(&job.img, &job.weights, &job.bias, false),
                _ => golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false),
            };
            assert_eq!(r.output.data(), want.data(), "job {}", r.id);
        }
        pool.shutdown();
    }

    /// Test backend that fails every job (stands in for a dropped
    /// remote peer or wedged device).
    struct FailingBackend;

    impl ConvBackend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing-test"
        }
        fn capability(&self) -> Capability {
            Capability {
                standard3x3: true,
                depthwise: true,
                pointwise_as_3x3: true,
                accum: AccumMode::I32,
                paper_specs_only: false,
                spec_allowlist: None,
            }
        }
        fn cost_model(&self) -> CostModel {
            CostModel::HostMacs
        }
        fn run(&mut self, _job: &JobPayload) -> anyhow::Result<BackendRun> {
            anyhow::bail!("simulated peer drop")
        }
    }

    #[test]
    fn failing_worker_fails_over_to_capable_sibling() {
        // The tentpole contract: a worker that fails a job no longer
        // surfaces the error — the job is re-enqueued on the capable
        // sibling and *succeeds*. Ties in least-loaded selection go to
        // worker 0, so the single job deterministically hits the
        // failing worker first.
        let backends: Vec<Box<dyn ConvBackend>> =
            vec![Box::new(FailingBackend), Box::new(GoldenBackend::new())];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        let job = ConvJob::synthetic(7, QUICKSTART, 7);
        let want = golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false);
        pool.dispatch(batch_of(job, &tx));
        let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(res.error.is_none(), "failover must rescue the job: {:?}", res.error);
        assert_eq!(res.backend, "golden-cpu");
        assert_eq!(res.output.data(), want.data());
        // One failover hop, zero terminal failures; both queues drained.
        let m = &pool.metrics;
        assert_eq!(m.retried.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(m.failed.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(m.completed.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(pool.worker_loads(), vec![0, 0]);
        pool.shutdown();
    }

    /// Test backend that fails only its first job, then computes like
    /// golden — the partial-failure batch shape: job 0 dies, job 1
    /// lands on a worker that never loaded the batch's weights.
    struct FlakyFirstBackend {
        failed_once: bool,
    }

    impl ConvBackend for FlakyFirstBackend {
        fn name(&self) -> &'static str {
            "flaky-first-test"
        }
        fn capability(&self) -> Capability {
            Capability {
                standard3x3: true,
                depthwise: true,
                pointwise_as_3x3: true,
                accum: AccumMode::I32,
                paper_specs_only: false,
                spec_allowlist: None,
            }
        }
        fn cost_model(&self) -> CostModel {
            CostModel::HostMacs
        }
        fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
            if !self.failed_once {
                self.failed_once = true;
                anyhow::bail!("simulated mid-batch drop")
            }
            GoldenBackend::new().run(job)
        }
    }

    #[test]
    fn failover_hop_never_fakes_weight_reuse() {
        // The PR 7 accounting drift, now a hard contract: a 2-job batch
        // whose first job fails must not let ANY run claim a weight-DMA
        // it never paid —
        //   * the rescued job re-enters the rescue worker as position 0
        //     of a fresh batch: `weights_reused == false` and its DMA
        //     cycles are charged in full;
        //   * job 1, which succeeded on the flaky worker *after* job 0
        //     failed, is re-reported `weights_reused == false` (nothing
        //     loaded the weights there);
        //   * residency is not recorded on the flaky worker, so a
        //     follow-up job with the same weights pays cold again.
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(FlakyFirstBackend { failed_once: false }),
            Box::new(SimBackend::new(IpCoreConfig::default())),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        // Both jobs share one weight set (seed 7) — a legal closed batch.
        let (tx, rx) = channel();
        let jobs: Vec<Submission> = (0..2)
            .map(|i| Submission {
                job: ConvJob::synthetic(i, QUICKSTART, 7),
                reply: tx.clone(),
                enqueued: std::time::Instant::now(),
            })
            .collect();
        let weights_id = jobs[0].job.weights_id;
        pool.dispatch(Batch {
            spec: QUICKSTART,
            weights_id,
            kind: JobKind::Standard,
            accum: AccumMode::I32,
            jobs,
        });
        let mut results: Vec<ConvResult> = (0..2)
            .map(|_| rx.recv_timeout(Duration::from_secs(10)).unwrap())
            .collect();
        results.sort_by_key(|r| r.id);
        let rescued = &results[0];
        assert!(rescued.error.is_none(), "failover must rescue job 0: {:?}", rescued.error);
        assert_eq!(rescued.backend, "sim-ipcore-i32", "job 0 hops to the sibling");
        assert!(
            !rescued.weights_reused,
            "failover hop claimed a weight reuse it never paid"
        );
        // The rescue run's DMA is charged in full: identical to a cold
        // reference run, strictly more than a warm one.
        let job0 = ConvJob::synthetic(0, QUICKSTART, 7);
        let mut sim = SimBackend::new(IpCoreConfig::default());
        let cold = sim.run(&job0.payload(false)).unwrap().cycles;
        let warm = sim.run(&job0.payload(true)).unwrap().cycles;
        assert!(warm.dma_in < cold.dma_in, "test premise: residency discounts DMA");
        assert_eq!(rescued.cycles.dma_in, cold.dma_in, "rescued DMA charged in full");
        // Job 1 succeeded on the flaky worker, but job 0's failure means
        // nothing loaded the weights there: reuse is re-reported false.
        let survivor = &results[1];
        assert!(survivor.error.is_none());
        assert_eq!(survivor.backend, "flaky-first-test");
        assert!(
            !survivor.weights_reused,
            "mid-batch failure must clear the positional reuse flag"
        );
        assert_eq!(
            pool.metrics
                .weight_dma_skipped
                .load(std::sync::atomic::Ordering::Relaxed),
            0,
            "no skipped-DMA credit on a partial-failure batch"
        );
        // Residency was not faked: the same weights on the flaky worker
        // still run cold (job enters as position 0, resident_weights is
        // None there).
        let (tx2, rx2) = channel();
        let follow_up = ConvJob::synthetic(9, QUICKSTART, 7);
        assert_eq!(follow_up.weights_id, weights_id, "same weight set");
        pool.dispatch(batch_of(follow_up, &tx2));
        let r = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(r.error.is_none());
        assert!(
            !r.weights_reused,
            "residency recorded on a worker that never paid the load"
        );
        pool.shutdown();
    }

    #[test]
    fn lone_failing_worker_answers_error_results_and_releases_load() {
        // With no capable sibling there is nothing to fail over to: the
        // old contract holds — every job answered with an error result,
        // load released, nothing hangs.
        let backends: Vec<Box<dyn ConvBackend>> = vec![Box::new(FailingBackend)];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        for i in 0..3u64 {
            pool.dispatch(batch_of(ConvJob::synthetic(i, QUICKSTART, i), &tx));
        }
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 3, "every job answered, none hang");
        for r in &results {
            let err = r.error.as_ref().expect("error result");
            assert!(err.contains("simulated peer drop"), "{err}");
            assert!(r.output.is_empty());
        }
        // Failed jobs must release their queued cost like completed ones.
        assert_eq!(pool.worker_loads(), vec![0]);
        let m = &pool.metrics;
        assert_eq!(m.failed.load(std::sync::atomic::Ordering::Relaxed), 3);
        assert_eq!(m.retried.load(std::sync::atomic::Ordering::Relaxed), 0);
        pool.shutdown();
    }

    #[test]
    fn all_failing_pool_exhausts_bounded_attempts_then_errors() {
        // Four capable workers, all failing: the job must stop after
        // MAX_DISPATCH_ATTEMPTS distinct workers (initial + 2 hops),
        // answer exactly one error result, and leave every queue empty
        // — not ping-pong forever.
        let backends: Vec<Box<dyn ConvBackend>> = (0..4)
            .map(|_| Box::new(FailingBackend) as Box<dyn ConvBackend>)
            .collect();
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        pool.dispatch(batch_of(ConvJob::synthetic(1, QUICKSTART, 1), &tx));
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 1, "exactly one (error) answer");
        assert!(results[0].error.is_some());
        let m = &pool.metrics;
        assert_eq!(
            m.retried.load(std::sync::atomic::Ordering::Relaxed) as usize,
            MAX_DISPATCH_ATTEMPTS - 1
        );
        assert_eq!(m.failed.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(pool.worker_loads(), vec![0, 0, 0, 0]);
        pool.shutdown();
    }

    /// Golden-equivalent backend carrying a controllable health flag —
    /// stands in for a remote peer whose probe thread flips liveness.
    struct HealthyBackend {
        inner: GoldenBackend,
        health: Arc<WorkerHealth>,
    }

    impl ConvBackend for HealthyBackend {
        fn name(&self) -> &'static str {
            "healthy-test"
        }
        fn capability(&self) -> Capability {
            self.inner.capability()
        }
        fn cost_model(&self) -> CostModel {
            self.inner.cost_model()
        }
        fn health(&self) -> Option<Arc<WorkerHealth>> {
            Some(Arc::clone(&self.health))
        }
        fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
            self.inner.run(job)
        }
    }

    #[test]
    fn unhealthy_worker_is_routed_around_while_a_healthy_sibling_exists() {
        let h0 = WorkerHealth::new();
        let h1 = WorkerHealth::new();
        let backends: Vec<Box<dyn ConvBackend>> = vec![
            Box::new(HealthyBackend {
                inner: GoldenBackend::new(),
                health: Arc::clone(&h0),
            }),
            Box::new(HealthyBackend {
                inner: GoldenBackend::new(),
                health: Arc::clone(&h1),
            }),
        ];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        // Worker 0 goes unhealthy: traffic that would tie-break onto it
        // must route to worker 1 instead.
        h0.set_healthy(false);
        assert_eq!(pool.worker_health(), vec![false, true]);
        let (tx, rx) = channel();
        for i in 0..4u64 {
            pool.dispatch(batch_of(ConvJob::synthetic(i, QUICKSTART, i), &tx));
        }
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 4);
        for r in &results {
            assert!(r.error.is_none());
            assert_eq!(r.core, 1, "job {} routed to the unhealthy worker", r.id);
        }
        // All-unhealthy pool: capacity degrades, correctness does not —
        // jobs still route (and here still succeed).
        h1.set_healthy(false);
        let (tx, rx) = channel();
        pool.dispatch(batch_of(ConvJob::synthetic(9, QUICKSTART, 9), &tx));
        drop(tx);
        let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(res.error.is_none());
        // Recovery edges are counted once per outage.
        h0.set_healthy(true);
        h0.set_healthy(true);
        assert_eq!(pool.recovered_peers(), 1);
        pool.shutdown();
    }

    /// Golden-equivalent backend posing as a wire-v4 remote: carries a
    /// [`KnownWeights`] set and quotes Remote prices, plus a gate so
    /// the test can observe queued load before completion.
    struct CachedBackend {
        gate: std::sync::mpsc::Receiver<()>,
        known: Arc<KnownWeights>,
    }

    impl ConvBackend for CachedBackend {
        fn name(&self) -> &'static str {
            "cached-test"
        }
        fn capability(&self) -> Capability {
            Capability {
                standard3x3: true,
                depthwise: true,
                pointwise_as_3x3: true,
                accum: AccumMode::I32,
                paper_specs_only: false,
                spec_allowlist: None,
            }
        }
        fn cost_model(&self) -> CostModel {
            CostModel::Remote {
                workers: 1,
                class: crate::backend::RemotePeerClass::HostMacs,
            }
        }
        fn known_weights(&self) -> Option<Arc<KnownWeights>> {
            Some(Arc::clone(&self.known))
        }
        fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
            self.gate.recv().ok();
            GoldenBackend::new().run(job)
        }
    }

    #[test]
    fn known_weights_discount_charges_and_releases_symmetrically() {
        // A warm job (hash in the worker's KnownWeights) must be
        // charged the discounted quote and release exactly the same
        // amount; a cold job pays full price. Any charge/release
        // asymmetry would show up as a non-zero residual load.
        let known = KnownWeights::new();
        let (gate, gate_rx) = channel();
        let backends: Vec<Box<dyn ConvBackend>> = vec![Box::new(CachedBackend {
            gate: gate_rx,
            known: Arc::clone(&known),
        })];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let warm_job = ConvJob::synthetic(1, QUICKSTART, 1);
        known.mark_known(warm_job.weights_hash);
        let cold_job = ConvJob::synthetic(2, QUICKSTART, 2);
        assert_ne!(warm_job.weights_hash, cold_job.weights_hash, "premise");
        let model = CostModel::Remote {
            workers: 1,
            class: crate::backend::RemotePeerClass::HostMacs,
        };
        let warm = model.cost_cached(&QUICKSTART, JobKind::Standard, true) as i64;
        let cold = model.cost(&QUICKSTART, JobKind::Standard) as i64;
        assert!(warm < cold, "discount must be visible: {warm} vs {cold}");
        let (tx, rx) = channel();
        pool.dispatch(batch_of(warm_job, &tx));
        pool.dispatch(batch_of(cold_job, &tx));
        assert_eq!(pool.worker_loads(), vec![warm + cold]);
        gate.send(()).unwrap();
        gate.send(()).unwrap();
        drop(tx);
        let results: Vec<ConvResult> = rx.iter().collect();
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.error.is_none()));
        assert_eq!(pool.worker_loads(), vec![0], "charge/release must cancel");
        assert_eq!(pool.weight_cache_stats(), (0, 0, 0), "dispatch reads, never records");
        pool.shutdown();
    }

    #[test]
    fn traced_dispatch_records_a_tiled_request_tree_and_stage_histograms() {
        use crate::coordinator::request::TraceCtx;
        use crate::telemetry::validate_coverage;
        let sink = Arc::new(SpanSink::new());
        let backends: Vec<Box<dyn ConvBackend>> = vec![Box::new(GoldenBackend::new())];
        let pool = CorePool::with_backends_traced(
            backends,
            IpCoreConfig::default(),
            Some(Arc::clone(&sink)),
        );
        let (tx, rx) = channel();
        let mut job = ConvJob::synthetic(1, QUICKSTART, 1);
        job.trace = TraceCtx {
            id: 42,
            admission_us: 3,
            layer: None,
        };
        pool.dispatch(batch_of(job, &tx));
        let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(res.error.is_none());
        let spans = sink.snapshot();
        let check = validate_coverage(&spans).expect("request tree must tile");
        assert_eq!(check.roots, 1);
        // Admission + queue + dispatch + compute spans all present, the
        // dispatch hop worker-tagged.
        for want in [Stage::Admission, Stage::Queue, Stage::Dispatch, Stage::Compute] {
            assert!(
                spans.iter().any(|s| s.stage == want),
                "missing {want:?} span"
            );
        }
        let hop = spans.iter().find(|s| s.stage == Stage::Dispatch).unwrap();
        assert_eq!(hop.worker.as_deref(), Some("golden-cpu"));
        // The stage histograms recorded independently of the spans.
        let m = &pool.metrics;
        assert_eq!(m.stages.queue.count(), 1);
        assert_eq!(m.stages.compute.count(), 1);
        assert_eq!(m.stages.wire.count(), 0, "no socket crossed");
        assert_eq!(m.stages.request.count(), 1);
        pool.shutdown();
    }

    #[test]
    fn untraced_pool_records_no_spans_but_still_decomposes_stages() {
        let pool = CorePool::new(1, IpCoreConfig::default());
        assert!(pool.span_sink().is_none());
        let (batch, rx) = one_job_batch(2);
        pool.dispatch(batch);
        let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(res.error.is_none());
        // queue/compute figures ride every result, tracing or not.
        assert!(res.compute_us > 0 || res.queue_us > 0 || res.latency.as_micros() < 2);
        assert_eq!(pool.metrics.stages.queue.count(), 1);
        pool.shutdown();
    }

    #[test]
    fn pool_scrape_source_renders_counters_stages_and_worker_gauges() {
        let pool = CorePool::new(1, IpCoreConfig::default());
        let (batch, rx) = one_job_batch(3);
        pool.dispatch(batch);
        let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let body = pool.scrape_source().render_prometheus();
        assert!(body.contains("repro_completed_total 1"), "{body}");
        assert!(
            body.contains("repro_stage_latency_us_bucket{stage=\"request\""),
            "{body}"
        );
        assert!(
            body.contains("repro_stage_latency_us_bucket{stage=\"queue\""),
            "{body}"
        );
        assert!(
            body.contains("repro_worker_load{worker=\"sim-ipcore-i32-0\"}"),
            "{body}"
        );
        assert!(body.contains("repro_worker_healthy{worker=\"sim-ipcore-i32-0\"} 1"));
        pool.shutdown();
    }

    #[test]
    fn unroutable_batch_is_returned_not_lost() {
        let backends: Vec<Box<dyn ConvBackend>> = vec![Box::new(SimBackend::new(IpCoreConfig {
            mode: AccumMode::Wrap8,
            ..Default::default()
        }))];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, _rx) = channel();
        let job = ConvJob::synthetic_depthwise(1, LayerSpec::new(4, 8, 8, 4), 1);
        let batch = batch_of(job, &tx);
        let back = pool.try_dispatch(batch).expect_err("must not route");
        assert_eq!(back.kind, JobKind::Depthwise);
        assert_eq!(back.jobs.len(), 1);
        pool.shutdown();
    }
}
