//! FPGA device catalog for the synthesis-model (Table 1).
//!
//! Capacities are the public Xilinx figures; they are *verified against
//! the paper*: Table 1's percentages back-solve to exactly these LUT/FF
//! counts (5027/53200 = 9.45 %, 14522/141120 = 10.29 %, …), which both
//! validates the catalog and pins down which dies the authors used.
//!
//! Timing coefficients are calibrated per device so the logic-depth
//! model in [`super::resource`] lands on the paper's measured "Data
//! Path Delay"-derived fmax (112 / 93 / 161 MHz). We cannot run Vivado;
//! the coefficients make the model's structure (multiplier + 4-level
//! adder tree + routing) explicit and transparent.

/// FPGA technology family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    Series7,
    UltraScalePlus,
}

/// One catalog entry.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub family: Family,
    pub luts: u64,
    pub ffs: u64,
    /// 36Kb BRAM blocks (Zynq-7020: 140; ZU3EG: 216).
    pub bram36: u64,
    /// 8x8 multiplier logic delay, ns.
    pub t_mult_ns: f64,
    /// One adder-tree level delay, ns.
    pub t_add_ns: f64,
    /// Routing + clocking overhead on the critical path, ns.
    pub t_route_ns: f64,
}

/// Pynq Z2's part (Table 1 row 1).
pub const XC7Z020_CLG400: Device = Device {
    name: "xc7z020clg400-1",
    family: Family::Series7,
    luts: 53_200,
    ffs: 106_400,
    bram36: 140,
    t_mult_ns: 3.50,
    t_add_ns: 1.00,
    t_route_ns: 1.43,
};

/// Same die, larger package (Table 1 row 2) — the paper measures a
/// noticeably slower data path here; the extra routing absorbs it.
pub const XC7Z020_CLG484: Device = Device {
    name: "xc7z020clg484-1",
    family: Family::Series7,
    luts: 53_200,
    ffs: 106_400,
    bram36: 140,
    t_mult_ns: 3.50,
    t_add_ns: 1.00,
    t_route_ns: 3.25,
};

/// Zynq UltraScale+ ZU3EG (Table 1 row 3).
pub const XZCU3EG_SBVA484: Device = Device {
    name: "xzcu3eg-sbva484-1-i",
    family: Family::UltraScalePlus,
    luts: 70_560,
    ffs: 141_120,
    bram36: 216,
    t_mult_ns: 2.20,
    t_add_ns: 0.65,
    t_route_ns: 1.41,
};

/// The three devices of Table 1, in the paper's order.
pub const TABLE1_DEVICES: [Device; 3] = [XC7Z020_CLG400, XC7Z020_CLG484, XZCU3EG_SBVA484];

impl Device {
    /// Critical path: one 8x8 multiply, then the 4-level adder tree
    /// (⌈log2 9⌉ = 4) of a PCORE, plus routing.
    pub fn critical_path_ns(&self) -> f64 {
        self.t_mult_ns + 4.0 * self.t_add_ns + self.t_route_ns
    }

    /// Max frequency from the data-path delay, MHz.
    pub fn fmax_mhz(&self) -> f64 {
        1000.0 / self.critical_path_ns()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacities_backsolve_table1_percentages() {
        // 5027 LUTs on clg400 must print as 9.45%.
        assert!((5027.0 / XC7Z020_CLG400.luts as f64 * 100.0 - 9.45).abs() < 0.01);
        assert!((4959.0 / XC7Z020_CLG400.ffs as f64 * 100.0 - 4.66).abs() < 0.01);
        assert!((5243.0 / XC7Z020_CLG484.luts as f64 * 100.0 - 9.86).abs() < 0.01);
        assert!((11917.0 / XZCU3EG_SBVA484.luts as f64 * 100.0 - 16.89).abs() < 0.01);
        assert!((14522.0 / XZCU3EG_SBVA484.ffs as f64 * 100.0 - 10.29).abs() < 0.01);
    }

    #[test]
    fn fmax_matches_paper_within_one_mhz() {
        assert!((XC7Z020_CLG400.fmax_mhz() - 112.0).abs() < 1.0);
        assert!((XC7Z020_CLG484.fmax_mhz() - 93.0).abs() < 1.0);
        assert!((XZCU3EG_SBVA484.fmax_mhz() - 161.0).abs() < 1.0);
    }

    #[test]
    fn same_die_same_capacity() {
        assert_eq!(XC7Z020_CLG400.luts, XC7Z020_CLG484.luts);
        assert_eq!(XC7Z020_CLG400.ffs, XC7Z020_CLG484.ffs);
    }
}
