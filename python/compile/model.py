"""L2: JAX compute graphs for one convolutional layer and the edge CNN.

The paper's IP core processes *one convolutional layer at a time* (§3);
the L3 rust coordinator schedules layers. So the primary AOT unit is
:func:`conv_layer` — conv3x3 (Pallas, L1) + bias + optional fused ReLU —
exported once per distinct layer shape. :func:`cnn_forward` additionally
exports the whole edge CNN as a single fused HLO, which the ablation
bench compares against per-layer dispatch (fusion the FPGA core cannot
do is exactly what a compiler-backed runtime gets for free).

Everything here is build-time only: `aot.py` lowers these functions to
HLO text and the rust runtime executes the artifacts.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .kernels.conv3x3 import conv3x3
from .kernels.ref import maxpool2x2_ref


def conv_layer(img, w, bias, *, relu: bool = True):
    """One IP-core invocation: 3x3 valid conv + bias + optional ReLU."""
    return conv3x3(img, w, bias, relu=relu)


def maxpool2x2(img):
    """2x2/s2 max pool (runs as plain XLA ops between conv layers)."""
    return maxpool2x2_ref(img)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static shape of one conv layer (the coordinator's lookup key)."""

    c: int  # input channels
    h: int  # input height
    w: int  # input width
    k: int  # kernels / output channels
    relu: bool = True
    pool: bool = False  # 2x2 maxpool after the conv

    @property
    def oh(self) -> int:
        oh = self.h - 2
        return oh // 2 if self.pool else oh

    @property
    def ow(self) -> int:
        ow = self.w - 2
        return ow // 2 if self.pool else ow

    @property
    def name(self) -> str:
        tag = "p" if self.pool else ("r" if self.relu else "n")
        return f"conv3x3_c{self.c}h{self.h}w{self.w}k{self.k}{tag}"

    @property
    def macs(self) -> int:
        return (self.h - 2) * (self.w - 2) * 9 * self.c * self.k

    @property
    def psums(self) -> int:
        """PSUM count in the paper's accounting (§5.2): one per
        (output pixel, kernel, input channel)."""
        return (self.h - 2) * (self.w - 2) * self.k * self.c


def layer_fn(spec: ConvSpec):
    """Return the jit-able f(img, w, bias) for one layer spec."""

    def fn(img, w, bias):
        out = conv_layer(img, w, bias, relu=spec.relu)
        if spec.pool:
            out = maxpool2x2(out)
        return (out,)

    return fn


# ---------------------------------------------------------------------------
# The edge CNN (DESIGN.md E2E): a small AlexNet-shaped net whose every
# channel count is divisible by 4 — the property §4.1 of the paper builds
# the whole BRAM layout around (first layer excepted, as in the paper).
# Input: 32x32, 4 channels (RGB + border plane, as edge boards often pack).
# ---------------------------------------------------------------------------

EDGE_CNN: tuple[ConvSpec, ...] = (
    ConvSpec(c=4, h=32, w=32, k=8, relu=True, pool=True),  # -> 8 x 15 x 15
    ConvSpec(c=8, h=15, w=15, k=16, relu=True),  # -> 16 x 13 x 13
    ConvSpec(c=16, h=13, w=13, k=16, relu=True, pool=True),  # -> 16 x 5 x 5
    ConvSpec(c=16, h=5, w=5, k=32, relu=True),  # -> 32 x 3 x 3
    ConvSpec(c=32, h=3, w=3, k=32, relu=False),  # -> 32 x 1 x 1 logits
)


def cnn_forward(img, *params):
    """Whole edge CNN as one fused graph. ``params`` is (w0, b0, w1, b1, ...)."""
    x = img
    for i, spec in enumerate(EDGE_CNN):
        w, b = params[2 * i], params[2 * i + 1]
        x = conv_layer(x, w, b, relu=spec.relu)
        if spec.pool:
            x = maxpool2x2(x)
    return (x.reshape(-1),)  # (32,) logits


def edge_cnn_params_specs():
    """ShapeDtypeStructs for cnn_forward's parameter list, in order."""
    import jax

    specs = []
    for spec in EDGE_CNN:
        specs.append(jax.ShapeDtypeStruct((spec.k, spec.c, 3, 3), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((spec.k,), jnp.float32))
    return specs


# ---------------------------------------------------------------------------
# Exported AOT variants: every distinct layer shape the system serves.
# ---------------------------------------------------------------------------

QUICKSTART = ConvSpec(c=8, h=16, w=16, k=8, relu=False)
# §5.2's headline workload: 224x224x8 image, 8 kernels of 8 channels.
S52 = ConvSpec(c=8, h=224, w=224, k=8, relu=False)

VARIANTS: tuple[ConvSpec, ...] = (QUICKSTART, S52) + EDGE_CNN
