//! Request/response types flowing through the coordinator.

use crate::backend::{job_psums, JobKind, JobPayload};
use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::{LayerSpec, Tensor};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

/// Monotonically assigned request id.
pub type RequestId = u64;

/// One convolution-layer job (the unit a backend accepts).
#[derive(Clone, Debug)]
pub struct ConvJob {
    pub id: RequestId,
    pub spec: LayerSpec,
    /// Which conv flavour this is; drives capability-masked routing.
    pub kind: JobKind,
    /// Accumulator semantics the reply must carry. Routing matches this
    /// against [`crate::backend::Capability::accum`], so wrap-8 jobs in
    /// a mixed pool only ever reach wrap-8 silicon and production (I32)
    /// jobs never land on a wrapping core.
    pub accum: AccumMode,
    pub img: Tensor<u8>,
    /// `(K,C,3,3)` for standard/pointwise jobs, `(C,3,3)` for depthwise.
    /// Shared, not owned: registry submissions hand out the manifest's
    /// Arc so N requests against one model clone a pointer, never the
    /// weight bytes (wire v4 then hash-skips them too — zero-copy up to
    /// the wire).
    pub weights: Arc<Tensor<u8>>,
    pub bias: Arc<Vec<i32>>,
    /// Identifies the weight set: consecutive jobs sharing it on one
    /// core skip the weight DMA (weight-stationary across the batch).
    pub weights_id: u64,
    /// Content address of the weight bytes (FNV-1a over `weights`
    /// data) — the wire-v4 `weights_hash` and the key into a peer's
    /// [`crate::store::WeightStore`]. Unlike `weights_id` (which also
    /// folds in spec/kind for DMA-reuse grouping), this is a pure
    /// byte hash: two jobs share it iff their weight tensors are
    /// byte-identical.
    pub weights_hash: u64,
    /// Snapshot taken at dispatch time: whether the chosen worker's
    /// peer was believed to already hold `weights_hash`, so the wire
    /// weight term was discounted when this job's cost was charged.
    /// The release path must use the same flag — never re-derive it —
    /// or charge/release go asymmetric when residency changes
    /// mid-flight.
    pub wire_weights_cached: bool,
    /// Distributed-tracing context; default means tracing is off and
    /// the job costs nothing on the telemetry path.
    pub trace: TraceCtx,
}

/// Per-job tracing context, stamped at admission and carried through
/// dispatch, the wire, and stream hops.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    /// Trace id (one per request / per streamed image); `0` = tracing
    /// off — the span path is a no-op and the wire never sees a trace
    /// field.
    pub id: u64,
    /// Microseconds the request waited for admission before it was
    /// enqueued; the dispatcher uses it to anchor the request root span.
    pub admission_us: u64,
    /// `Some(layer)` when this job is one hop of a streamed inference —
    /// the stream driver owns the request root span then, and dispatch
    /// only records the per-hop children.
    pub layer: Option<u16>,
}

/// FNV-1a over every field that determines the weight-set layout.
///
/// The previous derivation (`spec.psums() ^ 0x5EED`) collided whenever
/// two different specs had equal PSUM counts (e.g. `8x16x16 k8` vs
/// `16x16x16 k4`), silently skipping the weight DMA across genuinely
/// different weight tensors.
pub fn weights_fingerprint(spec: &LayerSpec, kind: JobKind) -> u64 {
    fnv1a(spec, kind, &[])
}

/// [`weights_fingerprint`] with extra distinguishing state hashed in —
/// for per-request weight sets (explicit tensors over TCP) that must
/// never alias the synthetic per-spec sets. The salt is folded into the
/// FNV state, not XOR-ed on afterwards, so no salt value can cancel
/// back to an unsalted fingerprint.
pub fn weights_fingerprint_salted(spec: &LayerSpec, kind: JobKind, salt: u64) -> u64 {
    fnv1a(spec, kind, &[0x5A17_ED00, salt])
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over raw bytes, continuing from `seed` — the one hash
/// implementation behind every fingerprint in the coordinator (the
/// spec-field fingerprints here, and the TCP front-end's weight-byte
/// salting), so the scheme can't drift between files.
pub(crate) fn fnv1a_bytes_seeded(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over raw bytes from the standard offset basis.
pub(crate) fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    fnv1a_bytes_seeded(FNV_OFFSET, bytes)
}

fn fnv1a(spec: &LayerSpec, kind: JobKind, salt: &[u64]) -> u64 {
    let kind_tag = match kind {
        JobKind::Standard => 1u64,
        JobKind::Depthwise => 2,
        JobKind::PointwiseAs3x3 => 3,
    };
    let fields = [
        spec.c as u64,
        spec.h as u64,
        spec.w as u64,
        spec.k as u64,
        spec.relu as u64,
        spec.pool as u64,
        kind_tag,
    ];
    let mut h = FNV_OFFSET;
    for field in fields.iter().chain(salt) {
        h = fnv1a_bytes_seeded(h, &field.to_le_bytes());
    }
    h
}

impl ConvJob {
    /// Deterministically generate a standard job from a seed (trace
    /// replay).
    pub fn synthetic(id: RequestId, spec: LayerSpec, seed: u64) -> Self {
        let mut rng = crate::util::prng::Prng::new(seed);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        );
        let weights = Tensor::from_vec(
            &[spec.k, spec.c, 3, 3],
            rng.bytes_below(spec.k * spec.c * 9, 16),
        );
        let weights_hash = fnv1a_bytes(weights.data());
        ConvJob {
            id,
            spec,
            kind: JobKind::Standard,
            accum: AccumMode::I32,
            img,
            weights: Arc::new(weights),
            bias: Arc::new((0..spec.k).map(|_| rng.range_i64(0, 32) as i32).collect()),
            // Synthetic traces share one weight set per spec, like a
            // deployed model's fixed parameters.
            weights_id: weights_fingerprint(&spec, JobKind::Standard),
            weights_hash,
            wire_weights_cached: false,
            trace: TraceCtx::default(),
        }
    }

    /// Deterministically generate a depthwise job (`spec.k == spec.c`,
    /// weights `(C,3,3)`).
    pub fn synthetic_depthwise(id: RequestId, spec: LayerSpec, seed: u64) -> Self {
        assert_eq!(spec.k, spec.c, "depthwise spec must have K == C");
        let mut rng = crate::util::prng::Prng::new(seed);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        );
        let weights = Tensor::from_vec(&[spec.c, 3, 3], rng.bytes_below(spec.c * 9, 16));
        let weights_hash = fnv1a_bytes(weights.data());
        ConvJob {
            id,
            spec,
            kind: JobKind::Depthwise,
            accum: AccumMode::I32,
            img,
            weights: Arc::new(weights),
            bias: Arc::new((0..spec.c).map(|_| rng.range_i64(0, 32) as i32).collect()),
            weights_id: weights_fingerprint(&spec, JobKind::Depthwise),
            weights_hash,
            wire_weights_cached: false,
            trace: TraceCtx::default(),
        }
    }

    /// Require different accumulator semantics of the reply (the
    /// synthetic constructors default to production I32).
    pub fn with_accum(mut self, accum: AccumMode) -> Self {
        self.accum = accum;
        self
    }

    /// Kind-aware PSUM count (the load/metrics accounting unit).
    pub fn psums(&self) -> u64 {
        job_psums(&self.spec, self.kind)
    }

    /// Borrowed view a [`crate::backend::ConvBackend`] executes.
    pub fn payload(&self, weights_resident: bool) -> JobPayload<'_> {
        JobPayload {
            kind: self.kind,
            spec: &self.spec,
            img: &self.img,
            weights: &*self.weights,
            bias: self.bias.as_slice(),
            weights_resident,
            trace_id: self.trace.id,
        }
    }

    /// How many strong references share this job's weight blob — the
    /// zero-copy contract's observable (registry jobs add exactly one
    /// count per outstanding job; a deep copy would always read 1).
    pub fn weights_refcount(&self) -> usize {
        Arc::strong_count(&self.weights)
    }
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct ConvResult {
    pub id: RequestId,
    pub spec: LayerSpec,
    pub kind: JobKind,
    pub output: Tensor<i32>,
    /// Simulated hardware cycles (hw backends) or modelled equivalent
    /// cycles (host backends) for this job.
    pub cycles: CycleStats,
    /// Which pool worker ran it.
    pub core: usize,
    /// Name of the backend that ran it (e.g. `sim-ipcore-i32`).
    pub backend: &'static str,
    /// Host wall-clock latency from enqueue to completion.
    pub latency: Duration,
    /// Whether the weight DMA was skipped (batch reuse).
    pub weights_reused: bool,
    /// `Some(reason)` when the backend failed the job instead of
    /// computing it (e.g. a remote peer dropped mid-request). The job
    /// is *answered* — a failed backend must never hang the pool — but
    /// `output` is empty and carries no numerics.
    pub error: Option<String>,
    /// Microseconds the job sat dispatched-but-unstarted (queue stage);
    /// batch-granular — every job in a weight-stationary batch shares
    /// its batch's figure.
    pub queue_us: u64,
    /// Microseconds the winning backend call took (wall clock on the
    /// dispatching side; for remote workers this includes the wire).
    pub compute_us: u64,
}

impl ConvResult {
    /// Kind-aware PSUM count (matches [`ConvJob::psums`]).
    pub fn psums(&self) -> u64 {
        job_psums(&self.spec, self.kind)
    }
}

/// Envelope handed to the dispatcher: job + reply channel + enqueue time.
#[derive(Debug)]
pub struct Submission {
    pub job: ConvJob,
    pub reply: Sender<ConvResult>,
    pub enqueued: std::time::Instant,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QUICKSTART;

    #[test]
    fn synthetic_is_deterministic() {
        let a = ConvJob::synthetic(1, QUICKSTART, 9);
        let b = ConvJob::synthetic(1, QUICKSTART, 9);
        assert_eq!(a.img.data(), b.img.data());
        assert_eq!(a.weights.data(), b.weights.data());
        assert_eq!(a.bias, b.bias);
    }

    #[test]
    fn synthetic_shapes_match_spec() {
        let j = ConvJob::synthetic(2, QUICKSTART, 10);
        assert_eq!(j.img.shape(), &[8, 16, 16]);
        assert_eq!(j.weights.shape(), &[8, 8, 3, 3]);
        assert_eq!(j.bias.len(), 8);
        assert_eq!(j.kind, JobKind::Standard);
    }

    #[test]
    fn same_spec_shares_weights_id() {
        let a = ConvJob::synthetic(1, QUICKSTART, 1);
        let b = ConvJob::synthetic(2, QUICKSTART, 2);
        assert_eq!(a.weights_id, b.weights_id);
    }

    #[test]
    fn equal_psum_specs_no_longer_collide() {
        // 8x16x16 k8 and 16x16x16 k4 both have 12544 PSUMs; under the
        // old psums^0x5EED derivation they shared a weights_id and
        // wrongly skipped the weight DMA across different weight sets.
        let a = LayerSpec::new(8, 16, 16, 8);
        let b = LayerSpec::new(16, 16, 16, 4);
        assert_eq!(a.psums(), b.psums(), "test premise: equal PSUM counts");
        assert_ne!(
            weights_fingerprint(&a, JobKind::Standard),
            weights_fingerprint(&b, JobKind::Standard)
        );
    }

    #[test]
    fn fingerprint_separates_kind_and_flags() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        assert_ne!(
            weights_fingerprint(&spec, JobKind::Standard),
            weights_fingerprint(&spec, JobKind::Depthwise)
        );
        assert_ne!(
            weights_fingerprint(&spec, JobKind::Standard),
            weights_fingerprint(&spec.with_relu(), JobKind::Standard)
        );
    }

    #[test]
    fn salted_fingerprint_never_cancels_to_unsalted() {
        // The old `fingerprint ^ id ^ 0xF00D` scheme collapsed to the
        // plain per-spec fingerprint at id == 0xF00D, wrongly enabling
        // a weight-DMA skip between different weight sets.
        let spec = QUICKSTART;
        let base = weights_fingerprint(&spec, JobKind::Standard);
        for salt in [0u64, 1, 0xF00D, u64::MAX] {
            assert_ne!(weights_fingerprint_salted(&spec, JobKind::Standard, salt), base);
        }
        assert_ne!(
            weights_fingerprint_salted(&spec, JobKind::Standard, 1),
            weights_fingerprint_salted(&spec, JobKind::Standard, 2)
        );
    }

    #[test]
    fn weights_hash_is_a_pure_byte_address() {
        // Same bytes → same hash; different bytes → different hash,
        // even when the per-spec weights_id is (deliberately) shared.
        let a = ConvJob::synthetic(1, QUICKSTART, 1);
        let b = ConvJob::synthetic(2, QUICKSTART, 1);
        let c = ConvJob::synthetic(3, QUICKSTART, 2);
        assert_eq!(a.weights_hash, b.weights_hash);
        assert_eq!(a.weights_hash, fnv1a_bytes(a.weights.data()));
        assert_ne!(a.weights_hash, c.weights_hash);
        assert_eq!(a.weights_id, c.weights_id, "weights_id stays per-spec");
        assert!(!a.wire_weights_cached, "jobs are built cost-undiscounted");
    }

    #[test]
    fn synthetic_jobs_default_to_i32_accum() {
        use crate::hw::AccumMode;
        let j = ConvJob::synthetic(1, QUICKSTART, 1);
        assert_eq!(j.accum, AccumMode::I32);
        let w8 = ConvJob::synthetic(2, QUICKSTART, 2).with_accum(AccumMode::Wrap8);
        assert_eq!(w8.accum, AccumMode::Wrap8);
    }

    #[test]
    fn depthwise_job_shapes_and_psums() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        let j = ConvJob::synthetic_depthwise(3, spec, 7);
        assert_eq!(j.weights.shape(), &[8, 3, 3]);
        assert_eq!(j.bias.len(), 8);
        assert_eq!(j.kind, JobKind::Depthwise);
        assert_eq!(j.psums(), (8 * 8 * 8) as u64);
        assert!(j.psums() < spec.psums(), "no kernel axis in depthwise");
    }
}
