//! Quickstart: run one convolutional layer three ways and watch them
//! agree — the 60-second tour of the whole system.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! 1. golden CPU reference (`model::golden`)
//! 2. cycle-accurate simulated IP core (`hw::IpCore`) + its cycle report
//! 3. the AOT-compiled JAX+Pallas kernel under PJRT (`runtime::XlaRuntime`)

use repro::hw::ip_core::{gops_mac, gops_psum};
use repro::hw::{IpCore, IpCoreConfig};
use repro::model::{golden, Tensor, QUICKSTART};
use repro::runtime::XlaRuntime;
use repro::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    let spec = QUICKSTART;
    println!("layer: {} (C={} H={} W={} K={})", spec.name(), spec.c, spec.h, spec.w, spec.k);

    // Deterministic inputs.
    let mut rng = Prng::new(1);
    let img = Tensor::from_vec(
        &[spec.c, spec.h, spec.w],
        rng.bytes_below(spec.c * spec.h * spec.w, 128),
    );
    let wts = Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 32));
    let bias: Vec<i32> = (0..spec.k).map(|_| rng.range_i64(-20, 20) as i32).collect();

    // 1. golden reference.
    let want = golden::conv3x3_i32(&img, &wts, &bias, spec.relu);
    println!("golden:  out[0,0,0..4] = {:?}", &want.data()[..4]);

    // 2. simulated IP core.
    let mut core = IpCore::new(IpCoreConfig::default());
    let run = core.run_layer(&spec, &img, &wts, &bias, None)?;
    let sim = run.output.as_i32();
    println!("hw-sim:  out[0,0,0..4] = {:?}", &sim.data()[..4]);
    assert_eq!(sim.data(), want.data(), "simulator must match golden");
    println!(
        "hw-sim:  {} compute cycles -> {:.4} GOPS (psum) / {:.3} GOPS (MAC) @112MHz",
        run.cycles.compute,
        gops_psum(spec.psums(), run.cycles.compute, 112_000_000),
        gops_mac(spec.psums(), run.cycles.compute, 112_000_000),
    );

    // 3. XLA / PJRT (Pallas kernel, AOT). Needs the `xla` feature and
    // built artifacts; degrade to a two-way check otherwise.
    match XlaRuntime::with_default_registry() {
        Ok(mut rt) => {
            let xla = rt.run_layer(&spec, &img, &wts, &bias)?;
            println!("xla:     out[0,0,0..4] = {:?} (platform {})", &xla.data()[..4], rt.platform());
            for (a, b) in xla.data().iter().zip(want.data()) {
                assert_eq!(*a, *b as f32, "XLA must match golden");
            }
            println!("\nall three paths agree bit-exactly ✓");
        }
        Err(e) => {
            println!("xla:     skipped ({e})");
            println!("\ngolden and hw-sim agree bit-exactly ✓");
        }
    }
    Ok(())
}
