//! Minimal dense NCHW-ish tensor over a flat `Vec<T>`.
//!
//! Shapes are small fixed ranks (1–4); this is deliberately not a
//! general ndarray — the system only moves (C,H,W) feature maps,
//! (K,C,3,3) weights and (K,) biases.

use std::fmt;

#[derive(Clone, PartialEq, Eq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: fmt::Debug> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![T::default(); shape.iter().product()],
        }
    }
}

impl<T: Copy> Tensor<T> {
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Flat index for a 3-d (c, y, x) coordinate.
    #[inline]
    pub fn idx3(&self, c: usize, y: usize, x: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 3);
        (c * self.shape[1] + y) * self.shape[2] + x
    }

    /// Flat index for a 4-d (k, c, y, x) coordinate.
    #[inline]
    pub fn idx4(&self, k: usize, c: usize, y: usize, x: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((k * self.shape[1] + c) * self.shape[2] + y) * self.shape[3] + x
    }

    #[inline]
    pub fn at3(&self, c: usize, y: usize, x: usize) -> T {
        self.data[self.idx3(c, y, x)]
    }

    #[inline]
    pub fn at4(&self, k: usize, c: usize, y: usize, x: usize) -> T {
        self.data[self.idx4(k, c, y, x)]
    }

    #[inline]
    pub fn set3(&mut self, c: usize, y: usize, x: usize, v: T) {
        let i = self.idx3(c, y, x);
        self.data[i] = v;
    }

    pub fn map<U: Copy>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }
}

impl Tensor<u8> {
    /// Widen to the f32 carrier format the XLA artifacts consume.
    pub fn to_f32(&self) -> Tensor<f32> {
        self.map(|v| v as f32)
    }
}

impl Tensor<i32> {
    pub fn to_f32(&self) -> Tensor<f32> {
        self.map(|v| v as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_round_trips() {
        let mut t = Tensor::<i32>::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, 42);
        assert_eq!(t.at3(1, 2, 3), 42);
        assert_eq!(t.data()[t.idx3(1, 2, 3)], 42);
        assert_eq!(t.idx3(0, 0, 1), 1);
        assert_eq!(t.idx3(0, 1, 0), 4);
        assert_eq!(t.idx3(1, 0, 0), 12);
    }

    #[test]
    fn idx4_layout_is_kchw() {
        let t = Tensor::<u8>::zeros(&[2, 3, 3, 3]);
        assert_eq!(t.idx4(0, 0, 0, 1), 1);
        assert_eq!(t.idx4(0, 0, 1, 0), 3);
        assert_eq!(t.idx4(0, 1, 0, 0), 9);
        assert_eq!(t.idx4(1, 0, 0, 0), 27);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_checks_length() {
        Tensor::from_vec(&[2, 2], vec![1u8, 2, 3]);
    }

    #[test]
    fn widen_preserves_values() {
        let t = Tensor::from_vec(&[4], vec![0u8, 1, 127, 255]);
        assert_eq!(t.to_f32().data(), &[0.0, 1.0, 127.0, 255.0]);
    }
}
