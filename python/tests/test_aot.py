"""AOT pipeline tests: lowering, manifest integrity, and the §Perf L1
block-selection model (VMEM fit + MXU fill across all served shapes)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels.conv3x3 import (
    block_candidates,
    choose_blocks,
    conv3x3,
    vmem_footprint_bytes,
)
from compile.kernels.ref import conv3x3_ref


# --- lowering ---------------------------------------------------------------


def test_lowered_hlo_has_entry_and_output_shape():
    spec = model.ConvSpec(c=4, h=8, w=8, k=4, relu=False)
    text = aot.lower_layer(spec)
    assert text.lstrip().startswith("HloModule")
    assert "ENTRY" in text
    assert "f32[4,6,6]" in text  # output (K, OH, OW)


def test_lowered_pooled_layer_halves_spatial():
    spec = model.ConvSpec(c=4, h=10, w=10, k=4, relu=True, pool=True)
    text = aot.lower_layer(spec)
    assert "f32[4,4,4]" in text  # (10-2)//2 = 4


def test_manifest_entry_fields():
    spec = model.QUICKSTART
    e = aot.manifest_entry(spec)
    assert e["file"] == f"{spec.name}.hlo.txt"
    assert e["psums"] == spec.psums
    assert e["macs"] == spec.macs == spec.psums * 9
    assert e["inputs"][1] == [spec.k, spec.c, 3, 3]


# --- §Perf L1: block selection ----------------------------------------------


def test_block_candidates_are_legal():
    for c, k in [(8, 8), (3, 4), (16, 32), (1, 4)]:
        for kb, cb in block_candidates(c, k):
            assert k % kb == 0 and c % cb == 0


@pytest.mark.parametrize("spec", model.VARIANTS, ids=lambda s: s.name)
def test_chosen_blocks_fit_vmem_for_every_served_shape(spec):
    choice = choose_blocks(spec.c, spec.h, spec.w, spec.k)
    assert choice["fits_vmem_16MiB"]
    assert 0 < choice["mxu_fill"] <= 1
    # The chosen decomposition can't fill the MXU worse than the paper's
    # fixed 4 x C/4 split (it considers that split among the candidates).
    paper_fp = vmem_footprint_bytes(spec.c, spec.h, spec.w, spec.k)
    if paper_fp["fits_vmem_16MiB"]:
        assert choice["mxu_fill"] >= paper_fp["mxu_fill"] - 1e-12


def test_chosen_blocks_compute_correctly():
    rng = np.random.default_rng(5)
    c, h, w, k = 8, 12, 10, 8
    choice = choose_blocks(c, h, w, k)
    img = jnp.array(rng.integers(0, 100, (c, h, w)).astype(np.float32))
    wts = jnp.array(rng.integers(-20, 20, (k, c, 3, 3)).astype(np.float32))
    bias = jnp.array(rng.integers(-5, 5, (k,)).astype(np.float32))
    out = conv3x3(img, wts, bias, kblk=choice["kblk"], cblk=choice["cblk"])
    ref = conv3x3_ref(img, wts, bias)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    c=st.sampled_from([1, 2, 4, 8, 16, 32]),
    k=st.sampled_from([4, 8, 16, 32]),
    hw=st.integers(3, 64),
)
def test_footprint_model_consistency(c, k, hw):
    fp = vmem_footprint_bytes(c, hw, hw, k)
    assert fp["total_bytes"] == fp["image_bytes"] + fp["weight_bytes"] + fp["output_bytes"] + 4 * min(4, k)
    assert fp["total_bytes"] > 0
    choice = choose_blocks(c, hw, hw, k)
    # Chosen blocks never use more VMEM than the budget.
    chosen_fp = vmem_footprint_bytes(c, hw, hw, k, kblk=choice["kblk"], cblk=choice["cblk"])
    assert chosen_fp["total_bytes"] <= 16 * 2**20


def test_s52_block_report_for_experiments_md():
    """Prints the §Perf L1 numbers EXPERIMENTS.md quotes."""
    s = model.S52
    paper_split = vmem_footprint_bytes(s.c, s.h, s.w, s.k)
    chosen = choose_blocks(s.c, s.h, s.w, s.k)
    print(
        f"\nS52 paper-split footprint: {paper_split['total_bytes']/2**20:.2f} MiB, "
        f"mxu_fill={paper_split['mxu_fill']:.3f}"
    )
    print(
        f"S52 chosen blocks kblk={chosen['kblk']} cblk={chosen['cblk']}: "
        f"{chosen['total_bytes']/2**20:.2f} MiB, mxu_fill={chosen['mxu_fill']:.3f}"
    )
    assert chosen["mxu_fill"] >= paper_split["mxu_fill"]
