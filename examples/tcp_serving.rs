//! TCP serving demo: start the JSON-over-TCP front-end, fire concurrent
//! clients at it, verify numerics via checksums, report latency.
//!
//! ```bash
//! cargo run --release --example tcp_serving -- [--clients N] [--requests N]
//! ```

use repro::coordinator::tcp::{request_once, TcpServer};
use repro::coordinator::CoordinatorConfig;
use repro::model::{golden, QUICKSTART};
use repro::util::cli::Args;
use repro::util::json::Json;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[]).map_err(|e| anyhow::anyhow!(e))?;
    let clients = args.get_usize("clients", 8).map_err(|e| anyhow::anyhow!(e))?;
    let per_client = args.get_usize("requests", 16).map_err(|e| anyhow::anyhow!(e))?;

    let server = TcpServer::start("127.0.0.1:0", CoordinatorConfig::default().with_cores(4))?;
    println!("server on {} (4 simulated IP cores, wire protocol v2)", server.addr);

    // Expected checksum for each seed (client-side golden).
    let expected = |seed: u64| {
        let job = repro::coordinator::request::ConvJob::synthetic(0, QUICKSTART, seed);
        golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false)
            .data()
            .iter()
            .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF)
    };

    let t0 = Instant::now();
    let addr = server.addr;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut ok = 0usize;
                let mut lat_us = Vec::new();
                for r in 0..per_client {
                    let seed = (c * 1000 + r) as u64;
                    let req = Json::obj(vec![
                        ("id", Json::num(seed as f64)),
                        (
                            "spec",
                            Json::obj(vec![
                                ("c", Json::num(8u32)),
                                ("h", Json::num(16u32)),
                                ("w", Json::num(16u32)),
                                ("k", Json::num(8u32)),
                            ]),
                        ),
                        ("seed", Json::num(seed as f64)),
                    ]);
                    let t = Instant::now();
                    let resp = request_once(&addr, &req).expect("request");
                    lat_us.push(t.elapsed().as_micros() as u64);
                    if resp.get(&["ok"]).and_then(Json::as_bool) == Some(true) {
                        ok += 1;
                    }
                }
                (ok, lat_us)
            })
        })
        .collect();

    let mut total_ok = 0;
    let mut lats = Vec::new();
    for h in handles {
        let (ok, l) = h.join().expect("client thread");
        total_ok += ok;
        lats.extend(l);
    }
    let wall = t0.elapsed();
    lats.sort();

    // Spot-check numerics with one verified request.
    let seed = 424242u64;
    let req = Json::parse(&format!(
        r#"{{"id":1,"spec":{{"c":8,"h":16,"w":16,"k":8}},"seed":{seed}}}"#
    ))
    .unwrap();
    let resp = request_once(&addr, &req)?;
    let got = resp.get(&["checksum"]).and_then(Json::as_f64).unwrap() as i64;
    anyhow::ensure!(got == expected(seed), "checksum mismatch over the wire");

    let n = clients * per_client;
    println!(
        "{total_ok}/{n} ok in {wall:?} -> {:.0} req/s over TCP (incl. connect per request)",
        n as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50={}us p95={}us max={}us; checksum verified against local golden ✓",
        lats[lats.len() / 2],
        lats[(lats.len() as f64 * 0.95) as usize],
        lats.last().unwrap()
    );
    server.stop();
    Ok(())
}
