//! Bench: the XLA/PJRT runtime path — per-layer execution latency of
//! the AOT Pallas artifacts, compile-cache behaviour, and the fused
//! edge-CNN graph. This is the software baseline the simulated
//! accelerator is compared against in EXPERIMENTS.md.

use repro::bench_util::{black_box, Bencher};
use repro::model::network::EdgeCnn;
use repro::model::{LayerSpec, Tensor, QUICKSTART, S52};
use repro::runtime::XlaRuntime;
use repro::util::prng::Prng;
use std::time::Instant;

fn inputs(spec: &LayerSpec, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
    let mut rng = Prng::new(seed);
    (
        Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 128),
        ),
        Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 32)),
        vec![0i32; spec.k],
    )
}

fn main() -> anyhow::Result<()> {
    println!("=== bench: runtime (XLA/PJRT software path) ===");
    let mut rt = match XlaRuntime::with_default_registry() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIPPED: {e}");
            return Ok(());
        }
    };
    println!("platform: {}", rt.platform());
    let b = Bencher::default();

    // Cold compile cost (measured once — cache makes repeats free).
    {
        let t = Instant::now();
        let (img, wts, bias) = inputs(&QUICKSTART, 1);
        rt.run_layer(&QUICKSTART, &img, &wts, &bias)?;
        println!("cold compile+run quickstart: {:?}", t.elapsed());
    }

    // Warm per-layer latency.
    {
        let (img, wts, bias) = inputs(&QUICKSTART, 1);
        b.run_throughput("xla quickstart (MACs/s)", QUICKSTART.macs() as f64, || {
            black_box(rt.run_layer(&QUICKSTART, &img, &wts, &bias).unwrap())
        });
    }
    {
        let (img, wts, bias) = inputs(&S52, 52);
        let t = Instant::now();
        rt.run_layer(&S52, &img, &wts, &bias)?; // compile
        println!("cold compile+run s52: {:?}", t.elapsed());
        b.run_throughput("xla s52 224x224 (MACs/s)", S52.macs() as f64, || {
            black_box(rt.run_layer(&S52, &img, &wts, &bias).unwrap())
        });
    }

    // Fused CNN graph.
    {
        let net = EdgeCnn::new(42);
        let first = net.specs()[0];
        let img = EdgeCnn::sample_input(1, &first);
        let params: Vec<(Tensor<u8>, Vec<i32>)> = net
            .params
            .layers
            .iter()
            .map(|l| (l.weights.clone(), l.bias.clone()))
            .collect();
        let macs: u64 = net.specs().iter().map(|s| s.macs()).sum();
        b.run_throughput("xla fused edge-CNN (MACs/s)", macs as f64, || {
            black_box(rt.run_edge_cnn(&img, &params).unwrap())
        });
    }
    println!("compiled executables cached: {}", rt.compiled_count());
    Ok(())
}
