//! The edge CNN — rust mirror of `python/compile/model.py::EDGE_CNN`.
//!
//! Every intermediate channel count is divisible by 4, the property the
//! paper's §4.1 BRAM layout is built around. Parameters are generated
//! deterministically from a seed (no trained weights are shipped; the
//! end-to-end experiment validates *system* behaviour — numerics parity
//! across hw-sim / XLA / golden — not task accuracy).

use super::quant::{calibrate_from, Requant};
use super::tensor::Tensor;
use super::{golden, LayerSpec};
use crate::util::prng::Prng;

/// Layer chain of the edge CNN (input: 4×32×32).
pub fn edge_cnn_specs() -> Vec<LayerSpec> {
    vec![
        LayerSpec::new(4, 32, 32, 8).with_relu().with_pool(), // -> 8x15x15
        LayerSpec::new(8, 15, 15, 16).with_relu(),            // -> 16x13x13
        LayerSpec::new(16, 13, 13, 16).with_relu().with_pool(), // -> 16x5x5
        LayerSpec::new(16, 5, 5, 32).with_relu(),             // -> 32x3x3
        LayerSpec::new(32, 3, 3, 32),                         // -> 32x1x1 logits
    ]
}

/// One layer's parameters in the u8/i32 formats the hardware consumes.
#[derive(Clone, Debug)]
pub struct LayerParams {
    pub spec: LayerSpec,
    pub weights: Tensor<u8>,
    pub bias: Vec<i32>,
}

/// Whole-network parameters plus per-boundary requantisers.
#[derive(Clone, Debug)]
pub struct NetworkParams {
    pub layers: Vec<LayerParams>,
    /// Requantiser applied to each layer's i32 output before it becomes
    /// the next layer's u8 input (last layer's output stays i32 logits).
    pub requants: Vec<Requant>,
}

/// The edge CNN with deterministic parameters and calibrated requants.
pub struct EdgeCnn {
    pub params: NetworkParams,
}

impl EdgeCnn {
    /// Build with parameters from `seed`; requantisers are calibrated on
    /// one deterministic sample input (a real deployment calibrates on a
    /// dataset — same mechanism, more samples).
    pub fn new(seed: u64) -> Self {
        let specs = edge_cnn_specs();
        let mut rng = Prng::new(seed);
        let layers: Vec<LayerParams> = specs
            .iter()
            .map(|&spec| LayerParams {
                spec,
                // Small weights keep intermediate magnitudes meaningful
                // after repeated requantisation.
                weights: Tensor::from_vec(
                    &[spec.k, spec.c, 3, 3],
                    rng.bytes_below(spec.k * spec.c * 9, 8),
                ),
                bias: (0..spec.k).map(|_| rng.range_i64(0, 16) as i32).collect(),
            })
            .collect();

        // Calibration pass on one sample.
        let sample = Self::sample_input(seed ^ 0xCA11B, &specs[0]);
        let mut requants = Vec::new();
        let mut x = sample;
        for (i, lp) in layers.iter().enumerate() {
            let mut out = golden::conv3x3_i32(&x, &lp.weights, &lp.bias, lp.spec.relu);
            if lp.spec.pool {
                out = golden::maxpool2x2(&out);
            }
            if i + 1 < layers.len() {
                let q = calibrate_from(&out);
                x = q.apply(&out);
                requants.push(q);
            }
        }
        EdgeCnn {
            params: NetworkParams { layers, requants },
        }
    }

    /// Deterministic synthetic input image for a given seed.
    pub fn sample_input(seed: u64, first: &LayerSpec) -> Tensor<u8> {
        let mut rng = Prng::new(seed);
        Tensor::from_vec(
            &[first.c, first.h, first.w],
            rng.bytes_below(first.c * first.h * first.w, 256),
        )
    }

    pub fn specs(&self) -> Vec<LayerSpec> {
        self.params.layers.iter().map(|l| l.spec).collect()
    }

    /// Golden forward pass (u8 activations between layers, i32 logits).
    /// This is the reference the hw-simulator path and the XLA path are
    /// both compared against in the end-to-end tests.
    pub fn forward_golden(&self, img: &Tensor<u8>) -> Vec<i32> {
        let mut x = img.clone();
        let n = self.params.layers.len();
        for (i, lp) in self.params.layers.iter().enumerate() {
            let mut out = golden::conv3x3_i32(&x, &lp.weights, &lp.bias, lp.spec.relu);
            if lp.spec.pool {
                out = golden::maxpool2x2(&out);
            }
            if i + 1 < n {
                x = self.params.requants[i].apply(&out);
            } else {
                return out.into_data();
            }
        }
        unreachable!("network has at least one layer")
    }

    /// Classify: argmax over the 32 logits.
    pub fn classify_golden(&self, img: &Tensor<u8>) -> usize {
        let logits = self.forward_golden(img);
        argmax(&logits)
    }
}

/// Index of the maximal logit; ties break toward the **lowest** index,
/// matching numpy's `argmax` (the python mirror's classifier). A strict
/// `>` fold keeps the first maximal element, where `max_by_key` would
/// return the last.
pub fn argmax(xs: &[i32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Float analogue of [`argmax`]: first maximal index on ties; NaN
/// entries never win (any comparison against them is not `Greater`).
pub fn argmax_f32(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, v) in xs.iter().enumerate().skip(1) {
        if v.partial_cmp(&xs[best]) == Some(std::cmp::Ordering::Greater) {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_chain_is_consistent() {
        let specs = edge_cnn_specs();
        for pair in specs.windows(2) {
            assert_eq!(pair[0].k, pair[1].c, "channel handoff");
            assert_eq!(pair[0].oh(), pair[1].h, "height handoff");
            assert_eq!(pair[0].ow(), pair[1].w, "width handoff");
            assert_eq!(pair[1].c % 4, 0, "paper §4.1 divisibility");
        }
        let last = specs.last().unwrap();
        assert_eq!((last.k, last.oh(), last.ow()), (32, 1, 1));
    }

    #[test]
    fn forward_is_deterministic() {
        let net = EdgeCnn::new(7);
        let img = EdgeCnn::sample_input(123, &net.specs()[0]);
        assert_eq!(net.forward_golden(&img), net.forward_golden(&img));
    }

    #[test]
    fn different_inputs_give_different_logits() {
        let net = EdgeCnn::new(7);
        let a = EdgeCnn::sample_input(1, &net.specs()[0]);
        let b = EdgeCnn::sample_input(2, &net.specs()[0]);
        assert_ne!(net.forward_golden(&a), net.forward_golden(&b));
    }

    #[test]
    fn logits_have_expected_arity() {
        let net = EdgeCnn::new(42);
        let img = EdgeCnn::sample_input(5, &net.specs()[0]);
        assert_eq!(net.forward_golden(&img).len(), 32);
        assert!(net.classify_golden(&img) < 32);
    }

    #[test]
    fn requants_cover_inner_boundaries() {
        let net = EdgeCnn::new(7);
        assert_eq!(net.params.requants.len(), net.params.layers.len() - 1);
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1, 5, 3]), 1);
        assert_eq!(argmax(&[-1, -5]), 0);
        assert_eq!(argmax_f32(&[0.5, 2.0, 1.0]), 1);
    }

    #[test]
    fn argmax_ties_break_toward_lowest_index() {
        // numpy.argmax semantics: first maximal element wins.
        assert_eq!(argmax(&[5, 5, 1]), 0);
        assert_eq!(argmax(&[1, 7, 7, 7]), 1);
        assert_eq!(argmax(&[0, 0, 0]), 0);
        assert_eq!(argmax_f32(&[2.0, 2.0]), 0);
        assert_eq!(argmax_f32(&[-1.0, 3.5, 3.5, 0.0]), 1);
        // A later NaN never dethrones an established max.
        assert_eq!(argmax_f32(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax_f32(&[1.0, f32::NAN]), 0);
    }
}
