//! Three-way numerics parity: simulated IP core (I32 mode) ==
//! golden CPU conv == XLA/PJRT execution of the Pallas-lowered
//! artifacts, for every layer shape the registry serves.
//!
//! This is the cross-layer contract that makes the reproduction honest:
//! the same convolution, computed by (a) the cycle-accurate hardware
//! model, (b) a naive reference, and (c) the AOT-compiled JAX+Pallas
//! kernel running under PJRT, must agree bit-for-bit on integer data.

use repro::hw::{IpCore, IpCoreConfig};
use repro::model::{golden, LayerSpec, Tensor};
use repro::runtime::XlaRuntime;
use repro::util::prng::Prng;

fn case(spec: &LayerSpec, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
    let mut rng = Prng::new(seed);
    (
        Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 128),
        ),
        Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 32)),
        (0..spec.k).map(|_| rng.range_i64(-20, 20) as i32).collect(),
    )
}

fn runtime() -> Option<XlaRuntime> {
    match XlaRuntime::with_default_registry() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping xla parity (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn every_served_conv_spec_agrees_three_ways() {
    let Some(mut rt) = runtime() else { return };
    let specs = rt.registry.served_specs();
    assert!(!specs.is_empty());
    for (i, spec) in specs.iter().enumerate() {
        // Keep S52 (224x224) out of the exhaustive loop; it has its own test.
        if spec.h > 64 {
            continue;
        }
        let (img, wts, bias) = case(spec, 1000 + i as u64);

        // (a) golden
        let mut want = golden::conv3x3_i32(&img, &wts, &bias, spec.relu);
        if spec.pool {
            want = golden::maxpool2x2(&want);
        }
        // (b) simulated IP core (conv only — ReLU/pool live outside the core)
        let mut sim_core = IpCore::new(IpCoreConfig::default());
        let run = sim_core.run_layer(spec, &img, &wts, &bias, None).unwrap();
        let mut sim = run.output.as_i32();
        if spec.relu {
            for v in sim.data_mut() {
                *v = (*v).max(0);
            }
        }
        if spec.pool {
            sim = golden::maxpool2x2(&sim);
        }
        assert_eq!(sim.data(), want.data(), "{}: sim vs golden", spec.name());

        // (c) XLA artifact (fused relu/pool inside the HLO)
        let xla = rt.run_layer(spec, &img, &wts, &bias).unwrap();
        assert_eq!(xla.shape(), want.shape(), "{}", spec.name());
        for (a, b) in xla.data().iter().zip(want.data()) {
            assert_eq!(*a, *b as f32, "{}: xla vs golden", spec.name());
        }
    }
}

#[test]
fn s52_workload_agrees_sim_vs_xla() {
    let Some(mut rt) = runtime() else { return };
    let spec = repro::model::S52;
    let (img, wts, bias) = case(&spec, 52);
    let mut sim_core = IpCore::new(IpCoreConfig::default());
    let sim = sim_core
        .run_layer(&spec, &img, &wts, &bias, None)
        .unwrap()
        .output
        .as_i32();
    let xla = rt.run_layer(&spec, &img, &wts, &bias).unwrap();
    assert_eq!(xla.len(), sim.len());
    for (a, b) in xla.data().iter().zip(sim.data()) {
        assert_eq!(*a, *b as f32);
    }
}

#[test]
fn fused_edge_cnn_classifies_like_golden() {
    let Some(mut rt) = runtime() else { return };
    let net = repro::model::network::EdgeCnn::new(42);
    let first = net.specs()[0];
    for seed in [1u64, 2, 3] {
        let img = repro::model::network::EdgeCnn::sample_input(seed, &first);
        let golden_logits = net.forward_golden(&img);
        let golden_class = repro::model::network::argmax(&golden_logits);
        let params: Vec<(Tensor<u8>, Vec<i32>)> = net
            .params
            .layers
            .iter()
            .map(|l| (l.weights.clone(), l.bias.clone()))
            .collect();
        let xla_logits = rt.run_edge_cnn(&img, &params).unwrap();
        let xla_class = repro::model::network::argmax_f32(&xla_logits);
        // The fused artifact skips inter-layer requantisation (DESIGN.md
        // §5), so logits differ in scale — but the winning class on the
        // same weights tends to agree; assert shape + finiteness + report.
        assert_eq!(xla_logits.len(), 32);
        assert!(xla_logits.iter().all(|v| v.is_finite()));
        eprintln!("seed {seed}: golden class {golden_class}, fused-xla class {xla_class}");
    }
}

#[test]
fn repeated_execution_is_deterministic() {
    let Some(mut rt) = runtime() else { return };
    let spec = repro::model::QUICKSTART;
    let (img, wts, bias) = case(&spec, 9);
    let a = rt.run_layer(&spec, &img, &wts, &bias).unwrap();
    let b = rt.run_layer(&spec, &img, &wts, &bias).unwrap();
    assert_eq!(a.data(), b.data());
}
