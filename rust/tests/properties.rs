//! Property-based tests (in-tree PRNG harness; no proptest offline —
//! every case reports its seed so failures reproduce exactly).
//!
//! Invariants covered: simulator == golden across random shapes and
//! both accumulator modes; wrap8 == wide mod 256; block-partition
//! invariance of the BRAM layout; im2col lowering / weight-flattening
//! layout invariants; blocked-parallel GEMM ≡ naive GEMM; batcher
//! partition/no-mixing; quant monotonicity + range; pipeline timing
//! bounds; DMA cost monotonicity; latency-histogram quantile
//! monotonicity, merge ≡ combined recording, and count/sum agreement
//! under concurrent writers.

use repro::coordinator::batcher::Batcher;
use repro::coordinator::config::BatchConfig;
use repro::coordinator::request::{ConvJob, Submission};
use repro::hw::pipeline::{two_stage_pipelined, two_stage_serial};
use repro::hw::{AccumMode, IpCore, IpCoreConfig};
use repro::model::im2col::{gemm_i32, gemm_i32_blocked, im2col, weights_matrix};
use repro::model::{golden, quant::Requant, LayerSpec, Tensor};
use repro::util::prng::Prng;
use std::sync::mpsc::channel;

/// Random paper-compatible layer spec (small, so 100s of cases stay fast).
fn arb_spec(rng: &mut Prng) -> LayerSpec {
    let c = *rng.choose(&[1usize, 2, 3, 4, 5, 8, 12, 16]);
    let k = *rng.choose(&[4usize, 8, 12, 16]);
    let h = 3 + rng.below(10) as usize;
    let w = 3 + rng.below(10) as usize;
    let mut spec = LayerSpec::new(c, h, w, k);
    if rng.f64() < 0.3 {
        spec = spec.with_relu();
    }
    spec
}

fn arb_case(rng: &mut Prng, spec: &LayerSpec) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
    (
        Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        ),
        Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 256)),
        (0..spec.k).map(|_| rng.range_i64(-100, 100) as i32).collect(),
    )
}

#[test]
fn prop_simulator_equals_golden_i32() {
    for seed in 0..60u64 {
        let mut rng = Prng::new(seed);
        let spec = arb_spec(&mut rng);
        let (img, wts, bias) = arb_case(&mut rng, &spec);
        let run = IpCore::new(IpCoreConfig::default())
            .run_layer(&spec, &img, &wts, &bias, None)
            .unwrap_or_else(|e| panic!("seed {seed} spec {spec:?}: {e}"));
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        assert_eq!(
            run.output.as_i32().data(),
            want.data(),
            "seed {seed} spec {spec:?}"
        );
    }
}

#[test]
fn prop_wrap8_equals_wide_mod_256() {
    for seed in 100..140u64 {
        let mut rng = Prng::new(seed);
        let spec = arb_spec(&mut rng);
        let (img, wts, bias) = arb_case(&mut rng, &spec);
        let bias_pos: Vec<i32> = bias.iter().map(|b| b & 0xFF).collect();
        let wide = IpCore::new(IpCoreConfig::default())
            .run_layer(&spec, &img, &wts, &bias_pos, None)
            .unwrap()
            .output
            .as_i32();
        let wrap = IpCore::new(IpCoreConfig {
            mode: AccumMode::Wrap8,
            ..Default::default()
        })
        .run_layer(&spec, &img, &wts, &bias_pos, None)
        .unwrap();
        match wrap.output {
            repro::hw::ip_core::LayerOutput::Wrap8(t) => {
                for (w8, w32) in t.data().iter().zip(wide.data()) {
                    assert_eq!(*w8, (w32.rem_euclid(256)) as u8, "seed {seed}");
                }
            }
            _ => panic!("expected wrap8 output"),
        }
    }
}

#[test]
fn prop_pipeline_never_slower_than_serial_and_bounded() {
    for seed in 200..260u64 {
        let mut rng = Prng::new(seed);
        let n = 1 + rng.below(50) as usize;
        let steps: Vec<(u64, u64)> = (0..n)
            .map(|_| (rng.below(20), rng.below(20)))
            .collect();
        let p = two_stage_pipelined(&steps);
        let s = two_stage_serial(&steps);
        assert!(p <= s, "seed {seed}");
        // Lower bound: the slower stage's total plus the other stage's
        // single fastest element can't be beaten.
        let loads: u64 = steps.iter().map(|(l, _)| l).sum();
        let computes: u64 = steps.iter().map(|(_, c)| c).sum();
        assert!(p >= loads.max(computes), "seed {seed}");
    }
}

#[test]
fn prop_requant_monotone_and_in_range() {
    for seed in 300..340u64 {
        let mut rng = Prng::new(seed);
        let q = Requant::new(rng.below(12) as u32);
        let mut prev_out = 0u8;
        let mut prev_in = i32::MIN;
        for _ in 0..200 {
            let v = rng.range_i64(-1000, 1_000_000) as i32;
            let out = q.apply_scalar(v);
            if v >= prev_in {
                // monotone only along sorted inputs; sort pairwise:
            }
            let _ = (prev_in, prev_out);
            prev_in = v;
            prev_out = out;
        }
        // Explicit monotone check along a sorted ramp.
        let mut last = 0u8;
        for v in (0..100_000).step_by(991) {
            let out = q.apply_scalar(v);
            assert!(out >= last, "seed {seed}");
            last = out;
        }
    }
}

#[test]
fn prop_im2col_shape_and_patch_invariants() {
    // The lowering's contract: (OH*OW, C*9) patch matrix, valid-conv
    // output dims, and every entry is exactly its source pixel widened.
    for seed in 600..630u64 {
        let mut rng = Prng::new(seed);
        let c = *rng.choose(&[1usize, 2, 3, 5, 8]);
        let h = 3 + rng.below(10) as usize;
        let w = 3 + rng.below(10) as usize;
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let (p, oh, ow) = im2col(&img);
        assert_eq!((oh, ow), (h - 2, w - 2), "seed {seed}");
        assert_eq!(p.shape(), &[oh * ow, c * 9], "seed {seed}");
        let cols = c * 9;
        for row in 0..oh * ow {
            let (y, x) = (row / ow, row % ow);
            for ci in 0..c {
                for dy in 0..3 {
                    for dx in 0..3 {
                        assert_eq!(
                            p.data()[row * cols + (ci * 3 + dy) * 3 + dx],
                            img.at3(ci, y + dy, x + dx) as i32,
                            "seed {seed} row {row} c{ci} ({dy},{dx})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_weights_matrix_shape_and_layout() {
    for seed in 640..660u64 {
        let mut rng = Prng::new(seed);
        let c = *rng.choose(&[1usize, 3, 4, 8]);
        let k = *rng.choose(&[4usize, 8, 12]);
        let wts = Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256));
        let wm = weights_matrix(&wts);
        assert_eq!(wm.shape(), &[c * 9, k], "seed {seed}");
        for ki in 0..k {
            for ci in 0..c {
                for dy in 0..3 {
                    for dx in 0..3 {
                        assert_eq!(
                            wm.data()[((ci * 3 + dy) * 3 + dx) * k + ki],
                            wts.at4(ki, ci, dy, dx) as i32,
                            "seed {seed} k{ki} c{ci} ({dy},{dx})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_blocked_gemm_equals_naive_on_random_matrices() {
    // The routing-relevant bit-exactness claim, on shapes the conv path
    // never produces: non-multiple-of-block inner dims (the kk block is
    // 64), row counts that don't divide by the thread count, signed
    // entries, and degenerate single-row/column cases.
    for seed in 700..740u64 {
        let mut rng = Prng::new(seed);
        let m = 1 + rng.below(80) as usize;
        let kk = 1 + rng.below(150) as usize;
        let n = 1 + rng.below(40) as usize;
        let a = Tensor::from_vec(
            &[m, kk],
            (0..m * kk).map(|_| rng.range_i64(-100, 100) as i32).collect(),
        );
        let b = Tensor::from_vec(
            &[kk, n],
            (0..kk * n).map(|_| rng.range_i64(-100, 100) as i32).collect(),
        );
        let want = gemm_i32(&a, &b);
        let threads = *rng.choose(&[1usize, 2, 3, 4, 7, 16]);
        let got = gemm_i32_blocked(&a, &b, threads);
        assert_eq!(got.shape(), want.shape(), "seed {seed}");
        assert_eq!(
            got.data(),
            want.data(),
            "seed {seed} m={m} kk={kk} n={n} threads={threads}"
        );
    }
}

#[test]
fn prop_batcher_partitions_all_requests() {
    for seed in 400..430u64 {
        let mut rng = Prng::new(seed);
        let cfg = BatchConfig {
            max_batch: 1 + rng.below(6) as usize,
            max_skips: 1 + rng.below(8) as usize,
        };
        let mut batcher = Batcher::new(cfg);
        let n = 40;
        let mut closed = Vec::new();
        let specs = [
            LayerSpec::new(4, 8, 8, 4),
            LayerSpec::new(8, 6, 6, 8),
            LayerSpec::new(4, 10, 5, 4).with_relu(),
        ];
        for i in 0..n {
            let spec = *rng.choose(&specs);
            let (tx, _rx) = channel();
            closed.extend(batcher.push(Submission {
                job: ConvJob::synthetic(i, spec, i),
                reply: tx,
                enqueued: std::time::Instant::now(),
            }));
        }
        closed.extend(batcher.flush());
        // Partition: every id exactly once.
        let mut ids: Vec<u64> = closed
            .iter()
            .flat_map(|b| b.jobs.iter().map(|s| s.job.id))
            .collect();
        ids.sort();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "seed {seed}");
        // No batch mixes specs or exceeds max size.
        for b in &closed {
            assert!(b.jobs.len() <= cfg.max_batch, "seed {seed}");
            assert!(b.jobs.iter().all(|s| s.job.spec == b.spec), "seed {seed}");
        }
    }
}

#[test]
fn prop_dma_cost_monotone_and_superadditive_free() {
    use repro::hw::dma::DmaConfig;
    for seed in 500..520u64 {
        let mut rng = Prng::new(seed);
        let cfg = DmaConfig {
            bus_bytes: 1 + rng.below(16),
            burst_beats: 1 + rng.below(256),
            burst_setup_cycles: rng.below(16),
        };
        let mut prev = 0;
        for bytes in (0..5000).step_by(97) {
            let c = cfg.cycles_for(bytes);
            assert!(c >= prev, "seed {seed}: monotone");
            prev = c;
        }
        // Splitting a transfer never pays less (burst setup amortises).
        let a = rng.below(4000);
        let b = rng.below(4000);
        assert!(
            cfg.cycles_for(a + b) <= cfg.cycles_for(a) + cfg.cycles_for(b),
            "seed {seed}"
        );
    }
}

#[test]
fn prop_histogram_quantiles_monotone_in_q() {
    use repro::coordinator::metrics::LatencyHistogram;
    for seed in 800..830u64 {
        let mut rng = Prng::new(seed);
        let h = LatencyHistogram::new();
        let n = 1 + rng.below(400);
        for _ in 0..n {
            h.record_us(rng.below(2_000_000));
        }
        let mut last = 0u64;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = h.quantile_us(q);
            assert!(v >= last, "seed {seed} q={q}: quantile fell {v} < {last}");
            last = v;
        }
        // The interpolated tail orders correctly even inside one bucket.
        assert!(h.quantile_us(0.999) >= h.quantile_us(0.99), "seed {seed}");
    }
}

#[test]
fn prop_histogram_merge_equals_combined_recording() {
    use repro::coordinator::metrics::LatencyHistogram;
    for seed in 840..870u64 {
        let mut rng = Prng::new(seed);
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        let combined = LatencyHistogram::new();
        for _ in 0..1 + rng.below(300) {
            let v = rng.below(5_000_000);
            if rng.f64() < 0.5 {
                a.record_us(v);
            } else {
                b.record_us(v);
            }
            combined.record_us(v);
        }
        a.merge(&b);
        assert_eq!(a.bucket_counts(), combined.bucket_counts(), "seed {seed}");
        assert_eq!(a.sum_us(), combined.sum_us(), "seed {seed}");
        assert_eq!(a.count(), combined.count(), "seed {seed}");
        for q in [0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(
                a.quantile_us(q),
                combined.quantile_us(q),
                "seed {seed} q={q}"
            );
        }
    }
}

#[test]
fn prop_histogram_concurrent_writers_agree_on_count_and_sum() {
    use repro::coordinator::metrics::LatencyHistogram;
    use std::sync::Arc;
    for seed in 880..884u64 {
        let h = Arc::new(LatencyHistogram::new());
        let threads = 4u64;
        let per = 500u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Prng::new(seed ^ (t << 32));
                    let mut sum = 0u64;
                    for _ in 0..per {
                        let v = rng.below(1_000_000);
                        sum += v;
                        h.record_us(v);
                    }
                    sum
                })
            })
            .collect();
        let want_sum: u64 = handles.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(h.count(), threads * per, "seed {seed}: lost records");
        assert_eq!(h.sum_us(), want_sum, "seed {seed}: torn sum");
    }
}

#[test]
fn prop_quarter_span_partitions_channels() {
    use repro::hw::bram::quarter_span;
    for c in 1..200usize {
        let mut total = 0;
        let mut next = 0;
        for q in 0..4 {
            let (start, len) = quarter_span(c, q);
            assert_eq!(start, next);
            next += len;
            total += len;
        }
        assert_eq!(total, c);
    }
}
