//! LRU weight-blob store, content-addressed by FNV-1a byte hash.
//!
//! Each wire-v4 `TcpServer` owns one [`WeightStore`]: when a request
//! carries `weights_hash` instead of weight bytes, the connection
//! handler consults the store and either serves the resident blob (a
//! cache hit — no weight bytes crossed the wire) or answers a
//! `need_weights` frame so the client re-ships once. The store is
//! shared across every connection to the peer, which is what makes the
//! cache per-*peer*, not per-socket: the first tenant to ship a model's
//! weights warms them for everyone.
//!
//! **Capacity model.** The budget is denominated in bytes derived from
//! the board's BRAM catalog: `blocks × BRAM36_BYTES`
//! ([`crate::hw::capacity::BRAM36_BYTES`], default
//! [`crate::hw::device::XC7Z020_CLG400`]'s 140 blocks), and each blob
//! is charged what the IP core's memory organisation would actually
//! reserve for it — `demand(spec, mode).weight_bytes`, the 16-BMG
//! weight footprint — not its raw byte length. A blob whose charge
//! alone exceeds the whole budget is served but never cached (the
//! board could not hold it resident either).
//!
//! **Eviction.** Strict LRU: `get` refreshes recency, `insert` evicts
//! from the cold end until the newcomer fits. Eviction is invisible to
//! correctness — an evicted hash simply round-trips through
//! `need_weights` → re-ship → hit again (covered by the wire tests).
//!
//! Thread-safe behind one mutex: lookups are a hash-map probe plus a
//! recency splice, trivial next to the convolution they gate.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::hw::capacity::BRAM36_BYTES;

/// One resident blob and what the BRAM model charges for it.
struct StoredBlob {
    blob: Arc<Vec<u8>>,
    cost_bytes: u64,
}

struct StoreInner {
    map: HashMap<u64, StoredBlob>,
    /// Recency order, coldest at the front. Always mirrors `map`'s key
    /// set exactly.
    lru: VecDeque<u64>,
    used_bytes: u64,
}

/// Content-addressed LRU of weight blobs, capacity-bounded by a BRAM
/// byte budget.
pub struct WeightStore {
    capacity_bytes: u64,
    inner: Mutex<StoreInner>,
}

impl WeightStore {
    /// A store with an explicit byte budget.
    pub fn new(capacity_bytes: u64) -> Self {
        WeightStore {
            capacity_bytes,
            inner: Mutex::new(StoreInner {
                map: HashMap::new(),
                lru: VecDeque::new(),
                used_bytes: 0,
            }),
        }
    }

    /// A store budgeted as `blocks` 36Kb BRAM blocks — the natural way
    /// to size one from a device catalog entry (`Device::bram36`).
    pub fn with_bram36_blocks(blocks: u64) -> Self {
        Self::new(blocks.saturating_mul(BRAM36_BYTES))
    }

    /// Look up a blob by hash, refreshing its recency on hit.
    pub fn get(&self, hash: u64) -> Option<Arc<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        let blob = Arc::clone(&inner.map.get(&hash)?.blob);
        if let Some(pos) = inner.lru.iter().position(|&h| h == hash) {
            inner.lru.remove(pos);
            inner.lru.push_back(hash);
        }
        Some(blob)
    }

    /// Whether a hash is resident, without touching recency (the
    /// dispatcher-side probe; `get` is the serving path).
    pub fn contains(&self, hash: u64) -> bool {
        self.inner.lock().unwrap().map.contains_key(&hash)
    }

    /// Insert a blob under its hash, charging `cost_bytes` against the
    /// budget and evicting cold entries until it fits. Returns whether
    /// the blob is now resident: a blob whose charge alone exceeds the
    /// whole budget is *not* cached (the caller serves it inline and
    /// every future request re-ships), and inserting an
    /// already-resident hash just refreshes its recency.
    pub fn insert(&self, hash: u64, blob: Arc<Vec<u8>>, cost_bytes: u64) -> bool {
        if cost_bytes > self.capacity_bytes {
            return false;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.map.contains_key(&hash) {
            if let Some(pos) = inner.lru.iter().position(|&h| h == hash) {
                inner.lru.remove(pos);
                inner.lru.push_back(hash);
            }
            return true;
        }
        while inner.used_bytes + cost_bytes > self.capacity_bytes {
            let Some(cold) = inner.lru.pop_front() else {
                break;
            };
            if let Some(evicted) = inner.map.remove(&cold) {
                inner.used_bytes -= evicted.cost_bytes;
            }
        }
        inner.used_bytes += cost_bytes;
        inner.map.insert(hash, StoredBlob { blob, cost_bytes });
        inner.lru.push_back(hash);
        true
    }

    /// Resident blob count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently charged against the budget.
    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used_bytes
    }

    /// The byte budget this store was built with.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Resident hashes coldest-first (tests assert eviction order
    /// through this; not a serving API).
    pub fn lru_order(&self) -> Vec<u64> {
        self.inner.lock().unwrap().lru.iter().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(byte: u8, len: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![byte; len])
    }

    #[test]
    fn insert_then_get_round_trips_the_blob() {
        let store = WeightStore::new(1000);
        assert!(store.is_empty());
        assert!(store.insert(7, blob(3, 16), 100));
        assert_eq!(store.len(), 1);
        assert_eq!(store.used_bytes(), 100);
        let got = store.get(7).expect("resident");
        assert_eq!(&*got, &vec![3u8; 16]);
        assert!(store.get(8).is_none());
        assert!(store.contains(7));
        assert!(!store.contains(8));
    }

    #[test]
    fn eviction_is_strict_lru_order() {
        // Budget fits exactly two 100-byte blobs.
        let store = WeightStore::new(200);
        assert!(store.insert(1, blob(1, 4), 100));
        assert!(store.insert(2, blob(2, 4), 100));
        assert_eq!(store.lru_order(), vec![1, 2]);
        // A third insert evicts the coldest (1), not the newest.
        assert!(store.insert(3, blob(3, 4), 100));
        assert_eq!(store.lru_order(), vec![2, 3]);
        assert!(!store.contains(1));
        assert!(store.contains(2) && store.contains(3));
        assert_eq!(store.used_bytes(), 200);
    }

    #[test]
    fn get_refreshes_recency_so_hot_blobs_survive() {
        let store = WeightStore::new(200);
        assert!(store.insert(1, blob(1, 4), 100));
        assert!(store.insert(2, blob(2, 4), 100));
        // Touch 1: now 2 is the coldest.
        assert!(store.get(1).is_some());
        assert_eq!(store.lru_order(), vec![2, 1]);
        assert!(store.insert(3, blob(3, 4), 100));
        assert!(store.contains(1), "recently used blob must survive");
        assert!(!store.contains(2), "cold blob is the one evicted");
    }

    #[test]
    fn oversized_blob_is_served_but_never_cached() {
        let store = WeightStore::new(100);
        assert!(!store.insert(9, blob(9, 4), 101));
        assert!(store.is_empty());
        // And it did not evict anything to make room it could never use.
        assert!(store.insert(1, blob(1, 4), 100));
        assert!(!store.insert(9, blob(9, 4), 101));
        assert!(store.contains(1));
    }

    #[test]
    fn reinserting_a_resident_hash_refreshes_without_double_charging() {
        let store = WeightStore::new(200);
        assert!(store.insert(1, blob(1, 4), 100));
        assert!(store.insert(2, blob(2, 4), 100));
        // Re-insert 1 (a client re-shipped redundantly): recency
        // refreshes, the budget is not charged twice.
        assert!(store.insert(1, blob(1, 4), 100));
        assert_eq!(store.used_bytes(), 200);
        assert_eq!(store.lru_order(), vec![2, 1]);
        assert!(store.insert(3, blob(3, 4), 100));
        assert!(store.contains(1) && store.contains(3));
        assert!(!store.contains(2));
    }

    #[test]
    fn bram_block_constructor_prices_in_whole_blocks() {
        let store = WeightStore::with_bram36_blocks(2);
        assert_eq!(store.capacity_bytes(), 2 * BRAM36_BYTES);
    }

    #[test]
    fn eviction_frees_enough_for_a_larger_newcomer() {
        let store = WeightStore::new(350);
        assert!(store.insert(1, blob(1, 4), 100));
        assert!(store.insert(2, blob(2, 4), 100));
        assert!(store.insert(3, blob(3, 4), 100));
        // 250 bytes needs BOTH 1 and 2 evicted, not just one.
        assert!(store.insert(4, blob(4, 4), 250));
        assert_eq!(store.lru_order(), vec![3, 4]);
        assert_eq!(store.used_bytes(), 350);
    }
}
