//! Request/response types flowing through the coordinator.

use crate::hw::ip_core::CycleStats;
use crate::model::{LayerSpec, Tensor};
use std::sync::mpsc::Sender;
use std::time::Duration;

/// Monotonically assigned request id.
pub type RequestId = u64;

/// One convolution-layer job (the unit the IP core accepts).
#[derive(Clone, Debug)]
pub struct ConvJob {
    pub id: RequestId,
    pub spec: LayerSpec,
    pub img: Tensor<u8>,
    pub weights: Tensor<u8>,
    pub bias: Vec<i32>,
    /// Identifies the weight set: consecutive jobs sharing it on one
    /// core skip the weight DMA (weight-stationary across the batch).
    pub weights_id: u64,
}

impl ConvJob {
    /// Deterministically generate a job from a seed (trace replay).
    pub fn synthetic(id: RequestId, spec: LayerSpec, seed: u64) -> Self {
        let mut rng = crate::util::prng::Prng::new(seed);
        ConvJob {
            id,
            spec,
            img: Tensor::from_vec(
                &[spec.c, spec.h, spec.w],
                rng.bytes_below(spec.c * spec.h * spec.w, 256),
            ),
            weights: Tensor::from_vec(
                &[spec.k, spec.c, 3, 3],
                rng.bytes_below(spec.k * spec.c * 9, 16),
            ),
            bias: (0..spec.k).map(|_| rng.range_i64(0, 32) as i32).collect(),
            // Synthetic traces share one weight set per spec, like a
            // deployed model's fixed parameters.
            weights_id: spec.psums() ^ 0x5EED,
        }
    }
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct ConvResult {
    pub id: RequestId,
    pub spec: LayerSpec,
    pub output: Tensor<i32>,
    /// Simulated hardware cycles for this job.
    pub cycles: CycleStats,
    /// Which simulated core ran it.
    pub core: usize,
    /// Host wall-clock latency from enqueue to completion.
    pub latency: Duration,
    /// Whether the weight DMA was skipped (batch reuse).
    pub weights_reused: bool,
}

/// Envelope handed to the dispatcher: job + reply channel + enqueue time.
#[derive(Debug)]
pub struct Submission {
    pub job: ConvJob,
    pub reply: Sender<ConvResult>,
    pub enqueued: std::time::Instant,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QUICKSTART;

    #[test]
    fn synthetic_is_deterministic() {
        let a = ConvJob::synthetic(1, QUICKSTART, 9);
        let b = ConvJob::synthetic(1, QUICKSTART, 9);
        assert_eq!(a.img.data(), b.img.data());
        assert_eq!(a.weights.data(), b.weights.data());
        assert_eq!(a.bias, b.bias);
    }

    #[test]
    fn synthetic_shapes_match_spec() {
        let j = ConvJob::synthetic(2, QUICKSTART, 10);
        assert_eq!(j.img.shape(), &[8, 16, 16]);
        assert_eq!(j.weights.shape(), &[8, 8, 3, 3]);
        assert_eq!(j.bias.len(), 8);
    }

    #[test]
    fn same_spec_shares_weights_id() {
        let a = ConvJob::synthetic(1, QUICKSTART, 1);
        let b = ConvJob::synthetic(2, QUICKSTART, 2);
        assert_eq!(a.weights_id, b.weights_id);
    }
}
