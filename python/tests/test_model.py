"""L2 correctness: layer functions, the edge CNN graph, and the AOT manifest."""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import conv3x3_ref, maxpool2x2_ref

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def _params_for(spec: model.ConvSpec, rng):
    img = jnp.array(rng.integers(0, 100, (spec.c, spec.h, spec.w)).astype(np.float32))
    w = jnp.array(rng.integers(-30, 30, (spec.k, spec.c, 3, 3)).astype(np.float32))
    b = jnp.array(rng.integers(-10, 10, (spec.k,)).astype(np.float32))
    return img, w, b


@pytest.mark.parametrize("spec", model.VARIANTS[:1] + model.EDGE_CNN, ids=lambda s: s.name)
def test_layer_fn_matches_ref(spec):
    rng = np.random.default_rng(hash(spec.name) % 2**32)
    img, w, b = _params_for(spec, rng)
    (out,) = model.layer_fn(spec)(img, w, b)
    ref = conv3x3_ref(img, w, b, relu=spec.relu)
    if spec.pool:
        ref = maxpool2x2_ref(ref)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=0, atol=0)
    assert out.shape == (spec.k, spec.oh, spec.ow)


def test_edge_cnn_shapes_chain():
    """Each layer's output shape must equal the next layer's input shape —
    the divisible-by-4 BRAM handoff of §4.1."""
    layers = model.EDGE_CNN
    for prev, nxt in zip(layers, layers[1:]):
        assert (prev.k, prev.oh, prev.ow) == (nxt.c, nxt.h, nxt.w)
        assert nxt.c % 4 == 0, "paper §4.1: all intermediate channel counts /4"
        assert nxt.k % 4 == 0


def test_cnn_forward_equals_per_layer_composition():
    rng = np.random.default_rng(99)
    first = model.EDGE_CNN[0]
    img = jnp.array(rng.integers(0, 50, (first.c, first.h, first.w)).astype(np.float32))
    params = []
    for spec in model.EDGE_CNN:
        params.append(jnp.array(rng.integers(-8, 8, (spec.k, spec.c, 3, 3)).astype(np.float32)))
        params.append(jnp.array(rng.integers(-4, 4, (spec.k,)).astype(np.float32)))
    (fused,) = model.cnn_forward(img, *params)

    x = img
    for i, spec in enumerate(model.EDGE_CNN):
        x = conv3x3_ref(x, params[2 * i], params[2 * i + 1], relu=spec.relu)
        if spec.pool:
            x = maxpool2x2_ref(x)
    # The fused graph compounds 5 layers without the inter-layer
    # requantisation the serving path applies, so magnitudes exceed the
    # f32 exact-integer range (DESIGN.md §5) — compare with rtol instead.
    np.testing.assert_allclose(
        np.array(fused), np.array(x).reshape(-1), rtol=1e-3, atol=1e-2
    )
    assert fused.shape == (32,)


def test_s52_psum_count_matches_paper():
    """§5.2: the 224x224x8 (x) 8x3x3x8 workload is exactly 3,154,176 PSUMs."""
    assert model.S52.psums == 3_154_176
    assert model.S52.macs == 3_154_176 * 9


def test_psum_accounting():
    spec = model.ConvSpec(c=8, h=10, w=12, k=4)
    assert spec.psums == 8 * 10 * 8 * 4  # OHxOW = 8x10
    assert spec.macs == spec.psums * 9


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_consistent_with_variants():
    manifest = json.loads((ART / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    for spec in model.VARIANTS:
        entry = manifest["variants"][spec.name]
        assert entry["inputs"][0] == [spec.c, spec.h, spec.w]
        assert entry["output"] == [spec.k, spec.oh, spec.ow]
        assert (ART / entry["file"]).exists(), entry["file"]
        # f32 exactness guard (DESIGN.md §5): 9*C*127^2 within 2^24.
        assert 9 * spec.c * 127 * 127 < 2**24, spec.name
    assert "edge_cnn" in manifest["variants"]


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_hlo_artifacts_are_text_modules():
    manifest = json.loads((ART / "manifest.json").read_text())
    for name, entry in manifest["variants"].items():
        text = (ART / entry["file"]).read_text()
        assert text.lstrip().startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_lowering_quickstart_roundtrip():
    """Lower the quickstart layer here and check the HLO text parses back
    through jax's own parser entry count (smoke; rust does the real load)."""
    from compile import aot

    text = aot.lower_layer(model.QUICKSTART)
    assert text.lstrip().startswith("HloModule")
    assert f"f32[{model.QUICKSTART.k},{model.QUICKSTART.h - 2},{model.QUICKSTART.w - 2}]" in text
