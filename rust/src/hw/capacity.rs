//! BRAM capacity planning and strip tiling.
//!
//! §4.1 sizes every image BMG for "the largest possible image", which
//! silently caps the layer shapes the core can accept. This module
//! makes the cap explicit — a per-device BRAM budget check — and lifts
//! it: layers whose feature maps exceed the budget are split into
//! horizontal **strips with a 2-row halo** (3×3 valid conv loses 2
//! rows), each strip small enough for the BMGs. Strip outputs
//! concatenate to exactly the untiled result; the cost is re-fetching
//! the halo rows over the DMA, which the planner accounts.

use super::device::Device;
use super::ip_core::{CycleStats, IpCore, LayerOutput};
use super::AccumMode;
use crate::model::{LayerSpec, Tensor};
use crate::paper::{KH, N_CORES, N_PCORES};

/// Bytes per 36Kb BRAM block.
pub const BRAM36_BYTES: u64 = 36 * 1024 / 8;

/// BRAM demand of one layer on the IP core's memory organisation.
#[derive(Clone, Copy, Debug)]
pub struct BramDemand {
    pub image_bytes: u64,
    pub weight_bytes: u64,
    pub output_bytes: u64,
    /// 36Kb blocks, respecting the 4 + 16 + 4 BMG granularity (each BMG
    /// rounds up to whole blocks).
    pub blocks: u64,
}

/// Compute the demand for a layer in a given accumulator mode.
pub fn demand(spec: &LayerSpec, mode: AccumMode) -> BramDemand {
    let out_word: u64 = match mode {
        AccumMode::Wrap8 => 1,
        AccumMode::I32 => 4,
    };
    let img_per_bmg = (spec.c.div_ceil(N_CORES) * spec.h * spec.w) as u64;
    let wgt_per_bmg =
        (spec.k.div_ceil(N_PCORES) * spec.c.div_ceil(N_CORES) * 9) as u64;
    let out_per_bmg =
        (spec.k.div_ceil(N_PCORES) * spec.conv_oh() * spec.conv_ow()) as u64 * out_word;
    let blocks = N_CORES as u64 * img_per_bmg.div_ceil(BRAM36_BYTES)
        + (N_CORES * N_PCORES) as u64 * wgt_per_bmg.div_ceil(BRAM36_BYTES)
        + N_PCORES as u64 * out_per_bmg.div_ceil(BRAM36_BYTES);
    BramDemand {
        image_bytes: N_CORES as u64 * img_per_bmg,
        weight_bytes: (N_CORES * N_PCORES) as u64 * wgt_per_bmg,
        output_bytes: N_PCORES as u64 * out_per_bmg,
        blocks,
    }
}

/// Fit verdict for one layer on one device.
#[derive(Clone, Copy, Debug)]
pub struct FitReport {
    pub demand: BramDemand,
    pub device_blocks: u64,
    pub fits: bool,
    /// If it doesn't fit: max input rows per strip that do.
    pub max_strip_rows: Option<usize>,
}

/// Check whether `spec` fits a device's BRAM (one IP core instance,
/// leaving `reserve_frac` of the blocks for the rest of the design).
pub fn fits(spec: &LayerSpec, device: &Device, mode: AccumMode, reserve_frac: f64) -> FitReport {
    let budget = (device.bram36 as f64 * (1.0 - reserve_frac)) as u64;
    let d = demand(spec, mode);
    let fits = d.blocks <= budget;
    let max_strip_rows = if fits {
        None
    } else {
        // Largest strip height whose demand fits the budget.
        let mut lo = KH; // minimum useful strip
        let mut best = None;
        let mut hi = spec.h;
        while lo <= hi {
            let mid = (lo + hi) / 2;
            let strip = LayerSpec {
                h: mid,
                ..*spec
            };
            if demand(&strip, mode).blocks <= budget {
                best = Some(mid);
                lo = mid + 1;
            } else {
                if mid == 0 {
                    break;
                }
                hi = mid - 1;
            }
        }
        best
    };
    FitReport {
        demand: d,
        device_blocks: device.bram36,
        fits,
        max_strip_rows,
    }
}

/// Result of a tiled layer run.
#[derive(Debug)]
pub struct TiledRun {
    pub output: Tensor<i32>,
    pub strips: usize,
    /// Sum of per-strip cycle stats.
    pub cycles: CycleStats,
    /// Extra input bytes moved because halo rows are fetched twice.
    pub halo_bytes: u64,
}

/// Run a layer in horizontal strips of at most `max_rows` input rows
/// (each strip overlaps the next by `KH - 1` halo rows). Output equals
/// the untiled conv exactly. I32 mode only (tiling a wrapping
/// accumulator is equally valid but nobody should).
pub fn run_layer_tiled(
    core: &mut IpCore,
    spec: &LayerSpec,
    img: &Tensor<u8>,
    weights: &Tensor<u8>,
    bias: &[i32],
    max_rows: usize,
) -> anyhow::Result<TiledRun> {
    anyhow::ensure!(max_rows >= KH, "strip must hold at least one window row");
    anyhow::ensure!(
        core.config.mode == AccumMode::I32,
        "tiling supported in I32 mode"
    );
    let (oh, ow) = (spec.conv_oh(), spec.conv_ow());
    let mut output = Tensor::<i32>::zeros(&[spec.k, oh, ow]);
    let mut cycles = CycleStats::default();
    let mut strips = 0;
    let mut halo_bytes = 0u64;

    let mut out_row = 0usize;
    let mut in_row = 0usize;
    while out_row < oh {
        // Strip covers output rows [out_row, out_row + strip_oh).
        let strip_h = max_rows.min(spec.h - in_row);
        let strip_oh = strip_h - KH + 1;
        let strip_spec = LayerSpec {
            h: strip_h,
            ..*spec
        };
        // Slice input rows [in_row, in_row + strip_h).
        let mut strip_data = Vec::with_capacity(spec.c * strip_h * spec.w);
        for c in 0..spec.c {
            for y in in_row..in_row + strip_h {
                for x in 0..spec.w {
                    strip_data.push(img.at3(c, y, x));
                }
            }
        }
        let strip_img = Tensor::from_vec(&[spec.c, strip_h, spec.w], strip_data);
        if strips > 0 {
            halo_bytes += (spec.c * (KH - 1) * spec.w) as u64;
        }

        let run = core.run_layer(&strip_spec, &strip_img, weights, bias, None)?;
        let strip_out = match run.output {
            LayerOutput::I32(t) => t,
            LayerOutput::Wrap8(t) => t.map(|v| v as i32),
        };
        let copy_rows = strip_oh.min(oh - out_row);
        for k in 0..spec.k {
            for y in 0..copy_rows {
                for x in 0..ow {
                    output.set3(k, out_row + y, x, strip_out.at3(k, y, x));
                }
            }
        }

        cycles.compute += run.cycles.compute;
        cycles.load_visible += run.cycles.load_visible;
        cycles.load_hidden += run.cycles.load_hidden;
        cycles.dma_in += run.cycles.dma_in;
        cycles.dma_out += run.cycles.dma_out;
        cycles.total += run.cycles.total;

        strips += 1;
        out_row += copy_rows;
        in_row += copy_rows; // next strip starts KH-1 rows before the
                             // first unproduced output row = in_row.
    }

    Ok(TiledRun {
        output,
        strips,
        cycles,
        halo_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::device::{XC7Z020_CLG400, XZCU3EG_SBVA484};
    use crate::hw::IpCoreConfig;
    use crate::model::{golden, S52};
    use crate::util::prng::Prng;

    fn case(spec: &LayerSpec, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        (
            Tensor::from_vec(
                &[spec.c, spec.h, spec.w],
                rng.bytes_below(spec.c * spec.h * spec.w, 256),
            ),
            Tensor::from_vec(
                &[spec.k, spec.c, 3, 3],
                rng.bytes_below(spec.k * spec.c * 9, 256),
            ),
            (0..spec.k).map(|_| rng.range_i64(-9, 9) as i32).collect(),
        )
    }

    #[test]
    fn small_layers_fit_z7020() {
        let spec = LayerSpec::new(8, 16, 16, 8);
        let r = fits(&spec, &XC7Z020_CLG400, AccumMode::I32, 0.2);
        assert!(r.fits, "{r:?}");
        assert!(r.max_strip_rows.is_none());
    }

    #[test]
    fn s52_image_fits_but_i32_output_is_the_pressure() {
        // 224x224x8 image = 401KB image + 1.6MB i32 output.
        let r = fits(&S52, &XC7Z020_CLG400, AccumMode::I32, 0.2);
        // The Z-7020 has 140 x 4.5KB = 630KB of BRAM: S52 in I32 does NOT fit.
        assert!(!r.fits, "{r:?}");
        assert!(r.max_strip_rows.is_some());
        // In wrap8 (1-byte outputs, the paper's silicon) pressure is ~852KB:
        // still over budget -> the paper's own workload needs strips too.
        let r8 = fits(&S52, &XC7Z020_CLG400, AccumMode::Wrap8, 0.2);
        assert!(!r8.fits);
        // The bigger ZU3EG (216 blocks) in wrap8 gets closer.
        let rz = fits(&S52, &XZCU3EG_SBVA484, AccumMode::Wrap8, 0.2);
        assert!(rz.demand.blocks < r.demand.blocks * 2);
    }

    #[test]
    fn tiled_equals_untiled_exactly() {
        let spec = LayerSpec::new(4, 20, 9, 4);
        let (img, wts, bias) = case(&spec, 41);
        let mut core = IpCore::new(IpCoreConfig::default());
        let untiled = golden::conv3x3_i32(&img, &wts, &bias, false);
        for max_rows in [3, 4, 5, 7, 11, 20] {
            let tiled =
                run_layer_tiled(&mut core, &spec, &img, &wts, &bias, max_rows).unwrap();
            assert_eq!(
                tiled.output.data(),
                untiled.data(),
                "max_rows={max_rows}, strips={}",
                tiled.strips
            );
        }
    }

    #[test]
    fn strip_count_and_halo_accounting() {
        let spec = LayerSpec::new(4, 20, 9, 4);
        let (img, wts, bias) = case(&spec, 42);
        let mut core = IpCore::new(IpCoreConfig::default());
        let tiled = run_layer_tiled(&mut core, &spec, &img, &wts, &bias, 5).unwrap();
        // 18 output rows, 3 per strip -> 6 strips; 5 halos x 2 rows.
        assert_eq!(tiled.strips, 6);
        assert_eq!(tiled.halo_bytes, (4 * 2 * 9 * 5) as u64);
    }

    #[test]
    fn tiling_compute_overhead_is_zero() {
        // Strips recompute nothing: total compute cycles equal untiled.
        let spec = LayerSpec::new(4, 26, 11, 8);
        let (img, wts, bias) = case(&spec, 43);
        let mut core = IpCore::new(IpCoreConfig::default());
        let whole = core.run_layer(&spec, &img, &wts, &bias, None).unwrap();
        let tiled = run_layer_tiled(&mut core, &spec, &img, &wts, &bias, 6).unwrap();
        assert_eq!(tiled.cycles.compute, whole.cycles.compute);
        // ... the cost is DMA: halo rows move twice.
        assert!(tiled.cycles.dma_in > whole.cycles.dma_in);
    }

    #[test]
    fn planner_strip_rows_actually_fit() {
        let r = fits(&S52, &XC7Z020_CLG400, AccumMode::I32, 0.2);
        let rows = r.max_strip_rows.unwrap();
        let strip = LayerSpec { h: rows, ..S52 };
        let budget = (XC7Z020_CLG400.bram36 as f64 * 0.8) as u64;
        assert!(demand(&strip, AccumMode::I32).blocks <= budget);
        // And one more row would not fit.
        let over = LayerSpec { h: rows + 1, ..S52 };
        assert!(demand(&over, AccumMode::I32).blocks > budget);
    }

    #[test]
    fn rejects_too_small_strips() {
        let spec = LayerSpec::new(4, 10, 10, 4);
        let (img, wts, bias) = case(&spec, 44);
        let mut core = IpCore::new(IpCoreConfig::default());
        assert!(run_layer_tiled(&mut core, &spec, &img, &wts, &bias, 2).is_err());
    }
}
