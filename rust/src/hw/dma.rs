//! DMA / AXI4 transfer model (§3): the PS hands the IP core its inputs
//! through a DMA engine over AXI4; results stream back the same way.
//!
//! The model is a burst-transfer cost function: AXI4 moves one beat of
//! `bus_bytes` per cycle inside a burst, bursts are at most 256 beats,
//! and each burst pays an arbitration/address-phase setup cost. This is
//! enough to reproduce the load/compute pipeline trade-off and to run
//! the DMA-bandwidth ablation; it does not model interconnect
//! contention (one IP core == one AXI master, as in the paper).

/// AXI4 burst parameters.
#[derive(Clone, Copy, Debug)]
pub struct DmaConfig {
    /// Data bus width in bytes per beat (Zynq PS-PL HP ports: 8 bytes).
    pub bus_bytes: u64,
    /// Max beats per burst (AXI4: 256).
    pub burst_beats: u64,
    /// Setup cycles per burst (address phase + arbitration).
    pub burst_setup_cycles: u64,
}

/// Cumulative transfer statistics for one engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DmaStats {
    pub bytes: u64,
    pub bursts: u64,
    pub cycles: u64,
}

/// The DMA engine: pure cost model + stat accumulation.
#[derive(Clone, Debug, Default)]
pub struct Dma {
    pub config: DmaConfig,
    pub stats: DmaStats,
}

impl Default for DmaConfig {
    fn default() -> Self {
        DmaConfig {
            bus_bytes: 8,
            burst_beats: 256,
            burst_setup_cycles: 4,
        }
    }
}

impl Dma {
    pub fn new(config: DmaConfig) -> Self {
        Dma {
            config,
            stats: DmaStats::default(),
        }
    }

    /// Cycles to move `bytes` in one logical transfer; accumulates stats.
    pub fn transfer(&mut self, bytes: u64) -> u64 {
        let c = self.config.cycles_for(bytes);
        let beats = bytes.div_ceil(self.config.bus_bytes.max(1));
        self.stats.bytes += bytes;
        self.stats.bursts += beats.div_ceil(self.config.burst_beats.max(1));
        self.stats.cycles += c;
        c
    }
}

impl DmaConfig {
    /// Pure cost: cycles to move `bytes`.
    pub fn cycles_for(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let beats = bytes.div_ceil(self.bus_bytes.max(1));
        let bursts = beats.div_ceil(self.burst_beats.max(1));
        bursts * self.burst_setup_cycles + beats
    }

    /// Effective bandwidth in bytes/cycle for a given transfer size
    /// (asymptotically `bus_bytes`, less for short transfers).
    pub fn effective_bandwidth(&self, bytes: u64) -> f64 {
        let cycles = self.cycles_for(bytes);
        if cycles == 0 {
            0.0
        } else {
            bytes as f64 / cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_zero_cycles() {
        assert_eq!(DmaConfig::default().cycles_for(0), 0);
    }

    #[test]
    fn single_burst_cost() {
        let c = DmaConfig {
            bus_bytes: 4,
            burst_beats: 256,
            burst_setup_cycles: 4,
        };
        // 100 bytes = 25 beats, 1 burst -> 4 + 25.
        assert_eq!(c.cycles_for(100), 29);
    }

    #[test]
    fn multi_burst_pays_setup_per_burst() {
        let c = DmaConfig {
            bus_bytes: 1,
            burst_beats: 16,
            burst_setup_cycles: 10,
        };
        // 32 bytes = 32 beats = 2 bursts -> 20 + 32.
        assert_eq!(c.cycles_for(32), 52);
    }

    #[test]
    fn bandwidth_approaches_bus_width() {
        let c = DmaConfig::default();
        let bw = c.effective_bandwidth(1 << 20);
        assert!(bw > 7.8 && bw <= 8.0, "{bw}");
    }

    #[test]
    fn stats_accumulate() {
        let mut dma = Dma::new(DmaConfig::default());
        dma.transfer(64);
        dma.transfer(64);
        assert_eq!(dma.stats.bytes, 128);
        assert_eq!(dma.stats.bursts, 2);
        assert!(dma.stats.cycles >= 16);
    }

    #[test]
    fn monotone_in_bytes() {
        let c = DmaConfig::default();
        let mut prev = 0;
        for bytes in (0..10_000).step_by(173) {
            let cur = c.cycles_for(bytes);
            assert!(cur >= prev);
            prev = cur;
        }
    }
}
