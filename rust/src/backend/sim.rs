//! [`ConvBackend`] over the cycle-accurate simulated IP core.
//!
//! This is the paper's unit of deployment: one replica of the §4
//! architecture. Standard and pointwise-as-3×3 jobs go through
//! [`IpCore::run_layer`]; depthwise jobs go through the core's
//! depthwise path — previously a side API, now reached through the same
//! backend entry point as everything else.

use super::{BackendRun, Capability, ConvBackend, CostModel, JobKind, JobPayload};
use crate::hw::{AccumMode, IpCore, IpCoreConfig};

/// One simulated IP core behind the backend trait.
#[derive(Clone, Debug)]
pub struct SimBackend {
    core: IpCore,
}

impl SimBackend {
    pub fn new(config: IpCoreConfig) -> Self {
        SimBackend {
            core: IpCore::new(config),
        }
    }

    pub fn config(&self) -> IpCoreConfig {
        self.core.config
    }
}

impl ConvBackend for SimBackend {
    fn name(&self) -> &'static str {
        match self.core.config.mode {
            AccumMode::I32 => "sim-ipcore-i32",
            AccumMode::Wrap8 => "sim-ipcore-wrap8",
        }
    }

    fn capability(&self) -> Capability {
        Capability {
            standard3x3: true,
            // The depthwise mapping accumulates wide (production mode);
            // the wrap-8 silicon model declines those jobs.
            depthwise: self.core.config.mode == AccumMode::I32,
            pointwise_as_3x3: true,
            accum: self.core.config.mode,
            // run_layer rejects specs violating the §4.1 BRAM layout;
            // the mask must say so, or the dispatcher routes jobs here
            // that a host worker in the same pool would have served.
            paper_specs_only: true,
            spec_allowlist: None,
        }
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SimCycles
    }

    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
        match job.kind {
            JobKind::Standard | JobKind::PointwiseAs3x3 => {
                let run = self
                    .core
                    .run_layer(job.spec, job.img, job.weights, job.bias, None)?;
                let mut cycles = run.cycles;
                if job.weights_resident {
                    // Weight-stationary batch reuse: the weight portion
                    // of DmaIn is skipped; image bytes still move.
                    // Approximate by the weight fraction of the input
                    // transfer.
                    let w_bytes = job.weights.len() as u64;
                    let total_in = (job.img.len() + job.weights.len()) as u64
                        + 4 * job.bias.len() as u64;
                    let saved = cycles.dma_in * w_bytes / total_in.max(1);
                    cycles.dma_in -= saved;
                    if self.core.config.count_dma {
                        cycles.total -= saved;
                    }
                }
                Ok(BackendRun {
                    output: run.output.into_i32(),
                    cycles,
                    wire: None,
                })
            }
            JobKind::Depthwise => {
                // run_depthwise validates weights/bias against the
                // image; pin the image to the spec too, so cost, PSUM
                // accounting and the reply's spec stay truthful.
                anyhow::ensure!(
                    job.img.shape() == [job.spec.c, job.spec.h, job.spec.w],
                    "image shape {:?} != spec {:?}",
                    job.img.shape(),
                    job.spec
                );
                let run = self
                    .core
                    .run_depthwise(job.img, job.weights, job.bias, job.spec.relu)?;
                Ok(BackendRun {
                    output: run.output,
                    cycles: run.cycles,
                    wire: None,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::depthwise::golden_depthwise3x3;
    use crate::model::{golden, LayerSpec, Tensor, QUICKSTART};
    use crate::util::prng::Prng;

    fn standard_case(spec: &LayerSpec, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        (
            Tensor::from_vec(
                &[spec.c, spec.h, spec.w],
                rng.bytes_below(spec.c * spec.h * spec.w, 256),
            ),
            Tensor::from_vec(
                &[spec.k, spec.c, 3, 3],
                rng.bytes_below(spec.k * spec.c * 9, 256),
            ),
            (0..spec.k).map(|_| rng.range_i64(-50, 50) as i32).collect(),
        )
    }

    #[test]
    fn standard_job_matches_golden() {
        let spec = QUICKSTART;
        let (img, wts, bias) = standard_case(&spec, 31);
        let mut be = SimBackend::new(IpCoreConfig::default());
        let run = be
            .run(&JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .unwrap();
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        assert_eq!(run.output.data(), want.data());
        assert!(run.cycles.compute > 0);
    }

    #[test]
    fn depthwise_routes_through_the_backend_entry_point() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        let mut rng = Prng::new(32);
        let img = Tensor::from_vec(&[8, 10, 10], rng.bytes_below(800, 256));
        let wts = Tensor::from_vec(&[8, 3, 3], rng.bytes_below(72, 256));
        let bias: Vec<i32> = (0..8).map(|_| rng.range_i64(-10, 10) as i32).collect();
        let mut be = SimBackend::new(IpCoreConfig::default());
        let run = be
            .run(&JobPayload {
                kind: JobKind::Depthwise,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .unwrap();
        let want = golden_depthwise3x3(&img, &wts, &bias, false);
        assert_eq!(run.output.data(), want.data());
        // One active PCORE: 2 channel rounds x 64 windows x 8 cycles.
        assert_eq!(run.cycles.compute, 2 * 64 * 8);
    }

    #[test]
    fn resident_weights_discount_input_dma() {
        let spec = QUICKSTART;
        let (img, wts, bias) = standard_case(&spec, 33);
        let mut be = SimBackend::new(IpCoreConfig::default());
        let payload = |resident| JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: resident,
            trace_id: 0,
        };
        let cold = be.run(&payload(false)).unwrap();
        let warm = be.run(&payload(true)).unwrap();
        assert!(warm.cycles.dma_in < cold.cycles.dma_in);
        assert_eq!(warm.output.data(), cold.output.data());
    }

    #[test]
    fn wrap8_mode_declines_depthwise_by_capability() {
        let be = SimBackend::new(IpCoreConfig {
            mode: AccumMode::Wrap8,
            ..Default::default()
        });
        assert!(!be.capability().supports(JobKind::Depthwise));
        assert!(be.capability().supports(JobKind::Standard));
        assert_eq!(be.name(), "sim-ipcore-wrap8");
    }

    #[test]
    fn cost_model_tracks_actual_compute_cycles() {
        let spec = QUICKSTART;
        let (img, wts, bias) = standard_case(&spec, 34);
        let mut be = SimBackend::new(IpCoreConfig::default());
        let modelled = be.cost(&spec, JobKind::Standard);
        let run = be
            .run(&JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .unwrap();
        assert_eq!(modelled, run.cycles.compute);
    }
}
