//! MobileNet-lite: depthwise-separable blocks on the paper's core.
//!
//! §4.1 names MobileNet as a motivating workload, so the reproduction
//! must actually run one. A block is depthwise 3×3 (+ReLU) followed by
//! pointwise 1×1 (+ReLU); the simulated path uses
//! [`crate::hw::depthwise`]'s two mappings (single-PCORE depthwise,
//! zero-padded-3×3 pointwise) and reports the utilisation penalty the
//! fixed-function core pays — the quantitative answer to "can this IP
//! serve the network its own paper cites?".

use super::quant::{calibrate_from, Requant};
use super::tensor::Tensor;
use crate::hw::depthwise::{
    golden_depthwise3x3, golden_pointwise, pad1, pointwise_as_3x3,
};
use crate::hw::IpCore;
use crate::model::LayerSpec;
use crate::util::prng::Prng;

/// One depthwise-separable block's static shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpec {
    /// Input channels (= depthwise channels).
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Pointwise output channels.
    pub k: usize,
}

impl BlockSpec {
    /// Spatial size after the depthwise valid conv.
    pub fn dw_oh(&self) -> usize {
        self.h - 2
    }

    pub fn dw_ow(&self) -> usize {
        self.w - 2
    }
}

/// Block chain of the mobilenet-lite model (input 4×20×20), channels
/// divisible by 4 throughout, per §4.1.
pub fn mobilenet_lite_specs() -> Vec<BlockSpec> {
    vec![
        BlockSpec { c: 4, h: 20, w: 20, k: 8 },   // -> 8 x 18 x 18
        BlockSpec { c: 8, h: 18, w: 18, k: 16 },  // -> 16 x 16 x 16
        BlockSpec { c: 16, h: 16, w: 16, k: 16 }, // -> 16 x 14 x 14
    ]
}

/// Parameters of one block.
#[derive(Clone, Debug)]
pub struct BlockParams {
    pub spec: BlockSpec,
    /// Depthwise weights (C,3,3).
    pub dw: Tensor<u8>,
    pub dw_bias: Vec<i32>,
    /// Pointwise weights (K,C).
    pub pw: Tensor<u8>,
    pub pw_bias: Vec<i32>,
}

/// The network: blocks + calibrated requantisers after each conv.
pub struct MobileNetLite {
    pub blocks: Vec<BlockParams>,
    /// (after-depthwise, after-pointwise) per block; last pointwise raw.
    pub requants: Vec<(Requant, Option<Requant>)>,
}

impl MobileNetLite {
    pub fn new(seed: u64) -> Self {
        let specs = mobilenet_lite_specs();
        let mut rng = Prng::new(seed);
        let blocks: Vec<BlockParams> = specs
            .iter()
            .map(|&spec| BlockParams {
                spec,
                dw: Tensor::from_vec(&[spec.c, 3, 3], rng.bytes_below(spec.c * 9, 8)),
                dw_bias: (0..spec.c).map(|_| rng.range_i64(0, 8) as i32).collect(),
                pw: Tensor::from_vec(&[spec.k, spec.c], rng.bytes_below(spec.k * spec.c, 8)),
                pw_bias: (0..spec.k).map(|_| rng.range_i64(0, 8) as i32).collect(),
            })
            .collect();

        // Calibrate requants on one sample.
        let mut x = Self::sample_input(seed ^ 0xD1, &specs[0]);
        let mut requants = Vec::new();
        for (i, b) in blocks.iter().enumerate() {
            let dw_out = golden_depthwise3x3(&x, &b.dw, &b.dw_bias, true);
            let q_dw = calibrate_from(&dw_out);
            let dw_q = q_dw.apply(&dw_out);
            let pw_out = golden_pointwise(&dw_q, &b.pw, &b.pw_bias);
            if i + 1 < blocks.len() {
                let q_pw = calibrate_from(&pw_out);
                x = q_pw.apply(&pw_out);
                requants.push((q_dw, Some(q_pw)));
            } else {
                requants.push((q_dw, None));
            }
        }
        MobileNetLite { blocks, requants }
    }

    pub fn sample_input(seed: u64, first: &BlockSpec) -> Tensor<u8> {
        let mut rng = Prng::new(seed);
        Tensor::from_vec(
            &[first.c, first.h, first.w],
            rng.bytes_below(first.c * first.h * first.w, 256),
        )
    }

    /// Pure-software reference forward pass (final logits-map i32).
    pub fn forward_golden(&self, img: &Tensor<u8>) -> Tensor<i32> {
        let mut x = img.clone();
        let n = self.blocks.len();
        for (i, b) in self.blocks.iter().enumerate() {
            let dw = golden_depthwise3x3(&x, &b.dw, &b.dw_bias, true);
            let dw_q = self.requants[i].0.apply(&dw);
            let pw = golden_pointwise(&dw_q, &b.pw, &b.pw_bias);
            match &self.requants[i].1 {
                Some(q) => x = q.apply(&pw),
                None => {
                    assert_eq!(i, n - 1);
                    return pw;
                }
            }
        }
        unreachable!("network non-empty")
    }

    /// Run one image through the simulated core; returns (final map,
    /// total compute cycles, effective MAC utilisation 0..1).
    pub fn infer_sim(
        &self,
        core: &mut IpCore,
        img: &Tensor<u8>,
    ) -> anyhow::Result<(Tensor<i32>, u64, f64)> {
        let mut x = img.clone();
        let mut cycles = 0u64;
        let mut useful_macs = 0u64;
        let n = self.blocks.len();
        for (i, b) in self.blocks.iter().enumerate() {
            // Depthwise on the core.
            let dw = core.run_depthwise(&x, &b.dw, &b.dw_bias, true)?;
            cycles += dw.cycles.compute;
            useful_macs += (b.spec.c * b.spec.dw_oh() * b.spec.dw_ow() * 9) as u64;
            let dw_q = self.requants[i].0.apply(&dw.output);

            // Pointwise as zero-padded 3x3 on the core.
            let padded = pad1(&dw_q);
            let w3 = pointwise_as_3x3(&b.pw);
            let spec = LayerSpec::new(b.spec.c, b.spec.dw_oh() + 2, b.spec.dw_ow() + 2, b.spec.k);
            let run = core.run_layer(&spec, &padded, &w3, &b.pw_bias, None)?;
            cycles += run.cycles.compute;
            useful_macs += (b.spec.k * b.spec.c * b.spec.dw_oh() * b.spec.dw_ow()) as u64;

            match &self.requants[i].1 {
                Some(q) => x = q.apply(&run.output.as_i32()),
                None => {
                    assert_eq!(i, n - 1);
                    // 18 MACs/cycle is the core's standard-conv peak.
                    let util = useful_macs as f64 / (cycles as f64 * 18.0);
                    return Ok((run.output.as_i32(), cycles, util));
                }
            }
        }
        unreachable!("network non-empty")
    }
}

/// Standard-conv network of equal MAC count for the utilisation
/// comparison in the benches (EXPERIMENTS.md ABL).
pub fn equivalent_standard_macs(specs: &[BlockSpec]) -> u64 {
    specs
        .iter()
        .map(|b| ((b.c + b.k * b.c) * b.dw_oh() * b.dw_ow() * 9) as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::IpCoreConfig;

    #[test]
    fn sim_matches_golden_bit_exact() {
        let net = MobileNetLite::new(7);
        let img = MobileNetLite::sample_input(1, &mobilenet_lite_specs()[0]);
        let golden = net.forward_golden(&img);
        let mut core = IpCore::new(IpCoreConfig::default());
        let (sim, cycles, util) = net.infer_sim(&mut core, &img).unwrap();
        assert_eq!(sim.data(), golden.data());
        assert!(cycles > 0);
        // The fixed-function core runs depthwise-separable blocks at
        // well under a third of its standard-conv efficiency.
        assert!(util < 0.35, "util {util}");
        assert!(util > 0.01);
    }

    #[test]
    fn block_chain_is_consistent() {
        let specs = mobilenet_lite_specs();
        for pair in specs.windows(2) {
            assert_eq!(pair[0].k, pair[1].c);
            assert_eq!(pair[0].dw_oh(), pair[1].h);
            assert_eq!(pair[0].dw_ow(), pair[1].w);
            assert_eq!(pair[1].c % 4, 0, "§4.1 divisibility");
        }
    }

    #[test]
    fn deterministic_and_input_sensitive() {
        let net = MobileNetLite::new(9);
        let a = MobileNetLite::sample_input(1, &mobilenet_lite_specs()[0]);
        let b = MobileNetLite::sample_input(2, &mobilenet_lite_specs()[0]);
        assert_eq!(net.forward_golden(&a).data(), net.forward_golden(&a).data());
        assert_ne!(net.forward_golden(&a).data(), net.forward_golden(&b).data());
    }
}
