//! Model registry: named manifests of `model_id → ordered layers`,
//! each layer carrying its spec, kind, weight tensor and
//! content-address (FNV-1a byte hash of the weights).
//!
//! The registry is the client side of multi-tenant serving: instead of
//! shipping raw tensors per request, a tenant submits
//! `(model, layer, input)` and the layer's weights are resolved from
//! the manifest — always the *same bytes*, hence the same
//! `weights_hash`, hence (over wire v4) shipped to a peer at most once
//! per peer lifetime and served from its [`crate::store::WeightStore`]
//! thereafter. The built-in manifest set is deterministic from a seed:
//! model 0 is the repo's MobileNet-lite
//! ([`crate::model::mobilenet::MobileNetLite`]), lowered exactly the
//! way `infer_sim` lowers it (depthwise 3×3 blocks plus pointwise
//! layers pre-lowered to the padded-3×3 dataflow), and models 1..N are
//! synthetic tenants over a chainable mixed-kind layer stack with
//! per-model weight sets. Every manifest layer also carries its
//! *boundary* transform (post-layer requant, optional `pad1`), so a
//! whole network can be walked layer-by-layer across the pool — the
//! streaming mode ([`crate::coordinator::stream`]) and
//! [`ModelManifest::forward_golden`] both consume the same metadata.
//!
//! Everything here is ordinary `ConvJob` construction — the registry
//! changes *where tensors come from*, never what the backends compute,
//! so the parity contract (`rust/tests/backend_parity.rs`) covers
//! registry-built jobs like any others.

use crate::backend::JobKind;
use crate::coordinator::request::{
    fnv1a_bytes, weights_fingerprint_salted, ConvJob,
};
use crate::hw::depthwise::{golden_depthwise3x3, pad1, pointwise_as_3x3};
use crate::hw::AccumMode;
use crate::model::mobilenet::{mobilenet_lite_specs, MobileNetLite};
use crate::model::quant::{calibrate_from, Requant};
use crate::model::{golden, LayerSpec, Tensor};
use crate::util::prng::Prng;
use std::sync::Arc;

/// One layer of a manifest: everything needed to build a `ConvJob`
/// except the input image, plus the *boundary* transform that turns
/// this layer's i32 output into the next layer's u8 input — what the
/// streaming scheduler applies on the front between hops.
#[derive(Clone)]
pub struct LayerParams {
    pub spec: LayerSpec,
    pub kind: JobKind,
    pub weights: Arc<Tensor<u8>>,
    pub bias: Arc<Vec<i32>>,
    /// Content address: FNV-1a over the raw weight bytes — the wire
    /// v4 `weights_hash` and the [`crate::store::WeightStore`] key.
    pub weights_hash: u64,
    /// Requantiser applied to this layer's i32 output before it feeds
    /// the next layer; `None` on the final layer (raw logits out).
    /// `Requant::apply` clamps negatives to zero, so the boundary
    /// subsumes ReLU exactly like the `CnnScheduler`/mobilenet paths.
    pub post_requant: Option<Requant>,
    /// Zero-pad the requantised output by one pixel before the next
    /// layer — the mobilenet pointwise-as-3×3 layers consume pre-padded
    /// inputs (`pad1` in `infer_sim`).
    pub pad_next: bool,
}

impl LayerParams {
    fn new(spec: LayerSpec, kind: JobKind, weights: Tensor<u8>, bias: Vec<i32>) -> Self {
        let weights_hash = fnv1a_bytes(weights.data());
        LayerParams {
            spec,
            kind,
            weights: Arc::new(weights),
            bias: Arc::new(bias),
            weights_hash,
            post_requant: None,
            pad_next: false,
        }
    }

    fn with_boundary(mut self, post_requant: Option<Requant>, pad_next: bool) -> Self {
        self.post_requant = post_requant;
        self.pad_next = pad_next;
        self
    }

    /// Apply this layer's boundary transform to its raw i32 output:
    /// optional 2×2 maxpool, requantise to u8 (clamping negatives —
    /// ReLU), then optional `pad1` for a pre-padded next layer. Returns
    /// `None` on the final layer, whose i32 output *is* the logits.
    pub fn boundary(&self, out: &Tensor<i32>) -> Option<Tensor<u8>> {
        let q = self.post_requant?;
        let pooled;
        let out = if self.spec.pool {
            pooled = golden::maxpool2x2(out);
            &pooled
        } else {
            out
        };
        let x = q.apply(out);
        Some(if self.pad_next { pad1(&x) } else { x })
    }
}

/// One model: an id and its ordered layers.
pub struct ModelManifest {
    pub id: String,
    pub layers: Vec<LayerParams>,
}

impl ModelManifest {
    /// Shape of the image a whole-network submission feeds layer 0.
    pub fn input_spec(&self) -> LayerSpec {
        self.layers[0].spec
    }

    /// Deterministic synthetic input image for a streaming submission —
    /// the same Prng scheme as [`ModelRegistry::job`], so a stream's
    /// reference forward can be recomputed from `(model, seed)` alone.
    pub fn sample_image(&self, seed: u64) -> Tensor<u8> {
        let s = self.input_spec();
        let mut rng = Prng::new(seed);
        Tensor::from_vec(&[s.c, s.h, s.w], rng.bytes_below(s.c * s.h * s.w, 256))
    }

    /// Build the `ConvJob` for one layer of this model over an explicit
    /// input tensor — the streaming scheduler's per-hop constructor.
    /// The manifest's weight/bias Arcs are *shared into* the job
    /// (pointer clone, never a byte copy).
    pub fn layer_job(
        &self,
        layer_idx: usize,
        job_id: u64,
        img: Tensor<u8>,
    ) -> anyhow::Result<ConvJob> {
        let layer = self.layers.get(layer_idx).ok_or_else(|| {
            anyhow::anyhow!("model {} has no layer {layer_idx}", self.id)
        })?;
        let spec = layer.spec;
        anyhow::ensure!(
            img.shape() == [spec.c, spec.h, spec.w].as_slice(),
            "model {} layer {layer_idx} wants input {:?}, got {:?}",
            self.id,
            [spec.c, spec.h, spec.w],
            img.shape()
        );
        Ok(ConvJob {
            id: job_id,
            spec,
            kind: layer.kind,
            accum: AccumMode::I32,
            img,
            weights: Arc::clone(&layer.weights),
            bias: Arc::clone(&layer.bias),
            weights_id: weights_fingerprint_salted(&spec, layer.kind, layer.weights_hash),
            weights_hash: layer.weights_hash,
            wire_weights_cached: false,
            trace: crate::coordinator::request::TraceCtx::default(),
        })
    }

    /// Whole-network CPU reference: run every layer's golden kernel and
    /// every boundary transform. For `mobilenet-lite` this is
    /// bit-identical to [`MobileNetLite::forward_golden`] (the lowering
    /// is exact); for synthetic tenants it *defines* the reference the
    /// streaming parity/chaos legs compare against.
    pub fn forward_golden(&self, img: &Tensor<u8>) -> Tensor<i32> {
        let mut x = img.clone();
        let n = self.layers.len();
        for (i, l) in self.layers.iter().enumerate() {
            let out = match l.kind {
                JobKind::Depthwise => {
                    golden_depthwise3x3(&x, &l.weights, l.bias.as_slice(), l.spec.relu)
                }
                _ => golden::conv3x3_i32(&x, &l.weights, l.bias.as_slice(), l.spec.relu),
            };
            match l.boundary(&out) {
                Some(next) => x = next,
                None => {
                    assert_eq!(i, n - 1, "only the final layer lacks a boundary requant");
                    return out;
                }
            }
        }
        unreachable!("manifest has at least one layer")
    }
}

/// The registry: every model this process can serve requests for.
pub struct ModelRegistry {
    models: Vec<ModelManifest>,
}

/// Synthetic-tenant layer library: paper-compatible shapes mixing
/// standard and depthwise kinds (the same routing paths as
/// `model/trace.rs` traffic) — and, since the streaming mode, a
/// *chainable* network: each layer's valid-conv output shape is the
/// next layer's input shape, so a synthetic tenant can be served
/// end-to-end (`ModelManifest::forward_golden`), not just per-layer.
fn synthetic_layer_specs() -> Vec<(LayerSpec, JobKind)> {
    vec![
        (LayerSpec::new(8, 16, 16, 8), JobKind::Standard), // -> 8x14x14
        (LayerSpec::new(8, 14, 14, 8).with_relu(), JobKind::Depthwise), // -> 8x12x12
        (LayerSpec::new(8, 12, 12, 8), JobKind::Standard), // -> 8x10x10 logits map
    ]
}

impl ModelRegistry {
    /// The built-in manifest set: `n_models` deterministic models from
    /// `seed`. Model 0 is MobileNet-lite (its blocks lowered to the
    /// depthwise + pointwise-as-3×3 job kinds the core serves); models
    /// 1.. are synthetic tenants, each with its own weight set (so
    /// distinct tenants never alias in the weight store).
    pub fn builtin(n_models: usize, seed: u64) -> Self {
        assert!(n_models >= 1, "a registry serves at least one model");
        let mut models = Vec::with_capacity(n_models);
        let net = MobileNetLite::new(seed);
        let mut layers = Vec::new();
        for (b, (q_dw, q_pw)) in net.blocks.iter().zip(&net.requants) {
            // Depthwise 3×3 (+fused ReLU), exactly as infer_sim runs it.
            // Its boundary is the block's calibrated after-depthwise
            // requant plus `pad1` — the pointwise layer consumes a
            // pre-padded input.
            let dw_spec =
                LayerSpec::new(b.spec.c, b.spec.h, b.spec.w, b.spec.c).with_relu();
            layers.push(
                LayerParams::new(
                    dw_spec,
                    JobKind::Depthwise,
                    b.dw.clone(),
                    b.dw_bias.clone(),
                )
                .with_boundary(Some(*q_dw), true),
            );
            // Pointwise 1×1 pre-lowered to the padded-3×3 dataflow: the
            // stored weights are already the centre-tapped (K,C,3,3)
            // tensor, so a registry job is explicit tensors on the wire.
            // Its boundary is the after-pointwise requant — absent on
            // the last block, whose raw i32 map is the logits.
            let pw_spec = LayerSpec::new(
                b.spec.c,
                b.spec.dw_oh() + 2,
                b.spec.dw_ow() + 2,
                b.spec.k,
            );
            layers.push(
                LayerParams::new(
                    pw_spec,
                    JobKind::PointwiseAs3x3,
                    pointwise_as_3x3(&b.pw),
                    b.pw_bias.clone(),
                )
                .with_boundary(*q_pw, false),
            );
        }
        models.push(ModelManifest {
            id: "mobilenet-lite".to_string(),
            layers,
        });
        for m in 1..n_models {
            // Per-model weight stream: tenants must not share bytes, or
            // the store could not tell their residency apart.
            let tenant_seed = seed ^ (m as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut rng = Prng::new(tenant_seed);
            let mut layers: Vec<LayerParams> = synthetic_layer_specs()
                .into_iter()
                .map(|(spec, kind)| {
                    let weight_len = match kind {
                        JobKind::Depthwise => spec.c * 9,
                        _ => spec.k * spec.c * 9,
                    };
                    let shape: Vec<usize> = match kind {
                        JobKind::Depthwise => vec![spec.c, 3, 3],
                        _ => vec![spec.k, spec.c, 3, 3],
                    };
                    let out_ch = match kind {
                        JobKind::Depthwise => spec.c,
                        _ => spec.k,
                    };
                    let weights =
                        Tensor::from_vec(&shape, rng.bytes_below(weight_len, 16));
                    let bias: Vec<i32> =
                        (0..out_ch).map(|_| rng.range_i64(0, 32) as i32).collect();
                    LayerParams::new(spec, kind, weights, bias)
                })
                .collect();
            // Calibrate boundary requants on one deterministic sample
            // forward, like EdgeCnn/MobileNetLite do — the chain is
            // what makes a synthetic tenant streamable end-to-end.
            let first = layers[0].spec;
            let mut cal = Prng::new(tenant_seed ^ 0xCA11B);
            let mut x = Tensor::from_vec(
                &[first.c, first.h, first.w],
                cal.bytes_below(first.c * first.h * first.w, 256),
            );
            let n = layers.len();
            for i in 0..n - 1 {
                let l = &layers[i];
                let out = match l.kind {
                    JobKind::Depthwise => {
                        golden_depthwise3x3(&x, &l.weights, l.bias.as_slice(), l.spec.relu)
                    }
                    _ => golden::conv3x3_i32(&x, &l.weights, l.bias.as_slice(), l.spec.relu),
                };
                let q = calibrate_from(&out);
                x = q.apply(&out);
                layers[i].post_requant = Some(q);
            }
            models.push(ModelManifest {
                id: format!("synthetic-{m}"),
                layers,
            });
        }
        ModelRegistry { models }
    }

    pub fn models(&self) -> &[ModelManifest] {
        &self.models
    }

    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    pub fn n_layers(&self, model_idx: usize) -> usize {
        self.models.get(model_idx).map_or(0, |m| m.layers.len())
    }

    /// Look a manifest up by id (the client-facing key).
    pub fn manifest(&self, id: &str) -> Option<&ModelManifest> {
        self.models.iter().find(|m| m.id == id)
    }

    /// Distinct weight blobs across every model — the number of
    /// inline weight ships a cold v4 peer should see at most.
    pub fn distinct_weight_hashes(&self) -> usize {
        let mut hashes: Vec<u64> = self
            .models
            .iter()
            .flat_map(|m| m.layers.iter().map(|l| l.weights_hash))
            .collect();
        hashes.sort_unstable();
        hashes.dedup();
        hashes.len()
    }

    /// Deterministic multi-tenant request mix: request `i` round-robins
    /// across models (maximal tenant interleave — the hard case for a
    /// weight cache) and draws its layer from a per-request Prng.
    pub fn pick(&self, i: u64, seed: u64) -> (usize, usize) {
        let model = (i % self.models.len() as u64) as usize;
        let layer = Prng::new(seed ^ (i << 1)).below(self.models[model].layers.len() as u64)
            as usize;
        (model, layer)
    }

    /// Build the `ConvJob` for one `(model, layer, input)` submission:
    /// manifest weights + a deterministic synthetic input image from
    /// `input_seed`. The weight fingerprint is derived from the actual
    /// bytes exactly like the wire's explicit-tensor path, so batching
    /// and DMA reuse treat registry jobs identically. The manifest's
    /// weight/bias blobs are shared into the job by Arc — N requests
    /// against one layer clone a pointer, never the tensor bytes.
    pub fn job(
        &self,
        model_idx: usize,
        layer_idx: usize,
        job_id: u64,
        input_seed: u64,
    ) -> anyhow::Result<ConvJob> {
        let model = self
            .models
            .get(model_idx)
            .ok_or_else(|| anyhow::anyhow!("no model {model_idx} in the registry"))?;
        let layer = model.layers.get(layer_idx).ok_or_else(|| {
            anyhow::anyhow!("model {} has no layer {layer_idx}", model.id)
        })?;
        let spec = layer.spec;
        let mut rng = Prng::new(input_seed);
        let img = Tensor::from_vec(
            &[spec.c, spec.h, spec.w],
            rng.bytes_below(spec.c * spec.h * spec.w, 256),
        );
        model.layer_job(layer_idx, job_id, img)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::depthwise::golden_depthwise3x3;
    use crate::model::golden;

    #[test]
    fn builtin_registry_is_deterministic() {
        let a = ModelRegistry::builtin(3, 42);
        let b = ModelRegistry::builtin(3, 42);
        assert_eq!(a.n_models(), 3);
        for (ma, mb) in a.models().iter().zip(b.models()) {
            assert_eq!(ma.id, mb.id);
            for (la, lb) in ma.layers.iter().zip(&mb.layers) {
                assert_eq!(la.weights_hash, lb.weights_hash);
                assert_eq!(la.weights.data(), lb.weights.data());
            }
        }
        // A different seed is a different weight universe.
        let c = ModelRegistry::builtin(3, 43);
        assert_ne!(
            a.models()[0].layers[0].weights_hash,
            c.models()[0].layers[0].weights_hash
        );
    }

    #[test]
    fn mobilenet_manifest_lowers_every_block_to_served_kinds() {
        let reg = ModelRegistry::builtin(1, 7);
        let m = reg.manifest("mobilenet-lite").expect("built-in model");
        let specs = mobilenet_lite_specs();
        assert_eq!(m.layers.len(), specs.len() * 2);
        for (i, b) in specs.iter().enumerate() {
            let dw = &m.layers[2 * i];
            assert_eq!(dw.kind, JobKind::Depthwise);
            assert_eq!((dw.spec.c, dw.spec.k), (b.c, b.c));
            assert!(dw.spec.relu, "mobilenet depthwise fuses ReLU");
            let pw = &m.layers[2 * i + 1];
            assert_eq!(pw.kind, JobKind::PointwiseAs3x3);
            assert_eq!((pw.spec.c, pw.spec.k), (b.c, b.k));
            assert_eq!(pw.spec.h, b.dw_oh() + 2, "pre-padded for the 3x3 dataflow");
            assert_eq!(pw.weights.shape(), &[b.k, b.c, 3, 3]);
        }
    }

    #[test]
    fn tenants_never_share_weight_hashes() {
        let reg = ModelRegistry::builtin(4, 11);
        let total: usize = reg.models().iter().map(|m| m.layers.len()).sum();
        assert_eq!(
            reg.distinct_weight_hashes(),
            total,
            "every layer of every tenant must have its own content address"
        );
    }

    #[test]
    fn registry_jobs_share_weights_across_requests_and_match_golden() {
        let reg = ModelRegistry::builtin(2, 5);
        // Two requests for the same layer: different inputs, identical
        // weight identity — the whole point of the registry.
        let a = reg.job(0, 0, 1, 100).unwrap();
        let b = reg.job(0, 0, 2, 200).unwrap();
        assert_eq!(a.weights_hash, b.weights_hash);
        assert_eq!(a.weights_id, b.weights_id);
        assert_ne!(a.img.data(), b.img.data());
        // Depthwise layer 0 is bit-exact against the golden reference.
        let want = golden_depthwise3x3(&a.img, &a.weights, &a.bias, a.spec.relu);
        assert_eq!(a.kind, JobKind::Depthwise);
        assert!(want.data().iter().any(|&v| v != 0));
        // A standard synthetic-tenant layer matches the raw conv.
        let s = reg.job(1, 0, 3, 300).unwrap();
        assert_eq!(s.kind, JobKind::Standard);
        let want_s = golden::conv3x3_i32(&s.img, &s.weights, &s.bias, false);
        assert_eq!(want_s.shape(), &[s.spec.k, s.spec.conv_oh(), s.spec.conv_ow()]);
    }

    #[test]
    fn job_rejects_out_of_range_submissions() {
        let reg = ModelRegistry::builtin(1, 3);
        assert!(reg.job(1, 0, 1, 1).is_err(), "unknown model");
        assert!(reg.job(0, 99, 1, 1).is_err(), "unknown layer");
    }

    #[test]
    fn registry_jobs_share_weight_blobs_by_arc_not_by_copy() {
        // The zero-copy contract: building jobs must clone the
        // manifest's Arc, never the tensor bytes. Strong counts are the
        // observable — manifest(1) + one per live job — and both jobs
        // point at literally the same allocation.
        let reg = ModelRegistry::builtin(1, 13);
        let layer = &reg.models()[0].layers[0];
        assert_eq!(Arc::strong_count(&layer.weights), 1);
        let a = reg.job(0, 0, 1, 100).unwrap();
        assert_eq!(Arc::strong_count(&layer.weights), 2, "one Arc per job, no deep copy");
        let b = reg.job(0, 0, 2, 200).unwrap();
        assert_eq!(Arc::strong_count(&layer.weights), 3);
        assert_eq!(a.weights_refcount(), 3);
        assert!(Arc::ptr_eq(&a.weights, &b.weights), "same allocation, not equal bytes");
        assert!(Arc::ptr_eq(&a.bias, &layer.bias));
        drop(a);
        drop(b);
        assert_eq!(Arc::strong_count(&layer.weights), 1, "jobs release their share");
    }

    #[test]
    fn synthetic_tenants_chain_and_carry_boundary_requants() {
        let reg = ModelRegistry::builtin(3, 19);
        for m in &reg.models()[1..] {
            // Shapes chain: each layer's valid-conv output is the next
            // layer's input (channels and spatial dims both).
            for pair in m.layers.windows(2) {
                let (a, b) = (&pair[0], &pair[1]);
                let out_ch = match a.kind {
                    JobKind::Depthwise => a.spec.c,
                    _ => a.spec.k,
                };
                assert_eq!(out_ch, b.spec.c, "channel handoff in {}", m.id);
                assert_eq!(a.spec.h - 2, b.spec.h, "height handoff in {}", m.id);
                assert_eq!(a.spec.w - 2, b.spec.w, "width handoff in {}", m.id);
            }
            // Every inner boundary requantises; the final layer is raw.
            let n = m.layers.len();
            for (i, l) in m.layers.iter().enumerate() {
                assert_eq!(l.post_requant.is_some(), i + 1 < n, "{} layer {i}", m.id);
                assert!(!l.pad_next, "synthetic tenants are not pre-padded");
            }
            // And at least one depthwise layer keeps mixed-kind routing.
            assert!(m.layers.iter().any(|l| l.kind == JobKind::Depthwise));
            // End-to-end reference is well-formed and deterministic.
            let img = m.sample_image(77);
            let logits = m.forward_golden(&img);
            assert_eq!(logits.data(), m.forward_golden(&img).data());
            assert!(logits.data().iter().any(|&v| v != 0));
        }
    }

    #[test]
    fn mobilenet_manifest_forward_matches_network_forward_bit_exact() {
        // The manifest's layer-chain + boundary metadata must reproduce
        // MobileNetLite::forward_golden exactly — requant, pad1 and the
        // final raw-logits layer all included. This is the invariant
        // the streaming scheduler's per-image verification rests on.
        let seed = 7;
        let reg = ModelRegistry::builtin(1, seed);
        let m = reg.manifest("mobilenet-lite").unwrap();
        let net = MobileNetLite::new(seed);
        for img_seed in [1u64, 2, 99] {
            let img = m.sample_image(img_seed);
            assert_eq!(
                m.forward_golden(&img).data(),
                net.forward_golden(&img).data(),
                "manifest lowering drifted from the network reference (img {img_seed})"
            );
        }
        // Boundary shape: dw layers requant+pad, pw layers requant only,
        // final pw layer raw.
        let n = m.layers.len();
        for (i, l) in m.layers.iter().enumerate() {
            match l.kind {
                JobKind::Depthwise => {
                    assert!(l.post_requant.is_some() && l.pad_next, "dw layer {i}")
                }
                _ => assert!(
                    !l.pad_next && (l.post_requant.is_some() == (i + 1 < n)),
                    "pw layer {i}"
                ),
            }
        }
    }

    #[test]
    fn pick_is_deterministic_and_covers_every_model() {
        let reg = ModelRegistry::builtin(3, 9);
        let mut seen = [false; 3];
        for i in 0..12u64 {
            let (m, l) = reg.pick(i, 17);
            assert_eq!((m, l), reg.pick(i, 17));
            assert!(l < reg.n_layers(m));
            seen[m] = true;
        }
        assert!(seen.iter().all(|&s| s), "round-robin touches every tenant");
    }
}
