//! `repro` — CLI for the FPGA-convolution-accelerator reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artefacts
//! (DESIGN.md §4):
//!
//! ```text
//! repro waveform [--vcd out.vcd]        Fig. 6: bit-exact waveform of one computing core
//! repro table1                          Table 1: resource model for all three devices
//! repro throughput [--cores N]          §5.2: S52 workload cycles + GOPS, 1..=20 cores
//! repro simulate --c C --h H --w W --k K [--wrap8] [--no-pipeline] [--dma]
//!                                       run one layer on the simulated IP core
//! repro infer [--seed S] [--xla]        edge CNN inference: hw-sim vs golden (vs XLA)
//! repro serve [--cores N] [--golden N] [--im2col N] [--remote host:port[,host:port...]]
//!             [--requests N] [--s52 F] [--dw F] [--models M] [--bench-json PATH]
//!             [--stream] [--images N] [--window W]
//!             [--trace-out FILE] [--metrics-addr A]
//!                                       closed-loop trace through the coordinator
//!                                       (--golden adds naive CPU fallback workers,
//!                                        --im2col adds threaded im2col+GEMM workers,
//!                                        --remote dials wire-protocol-v4 peers into
//!                                        the pool, --dw mixes in depthwise jobs,
//!                                        --models M switches to registry traffic:
//!                                        requests are (model, layer) submissions
//!                                        over M registered models instead of the
//!                                        synthetic trace;
//!                                        --stream switches to whole-network
//!                                        streaming inference: --images N images are
//!                                        walked through their model's layer chain
//!                                        across the pool, up to --window W in
//!                                        flight at once, every image checked
//!                                        bit-exact against the registry golden;
//!                                        --trace-out FILE enables distributed
//!                                        tracing and writes every request's span
//!                                        tree as Chrome trace-event JSON after the
//!                                        run — open in chrome://tracing / Perfetto;
//!                                        --metrics-addr A binds a read-only
//!                                        Prometheus scrape endpoint, live mid-run);
//!                                       writes a machine-readable BENCH_serving.json
//! repro serve-tcp [--addr A] [--cores N] [--golden N] [--im2col N] [--v2-only]
//!                                       serve wire protocol v4 over TCP (binary
//!                                       tensor frames + content-addressed weight
//!                                       store; --v2-only pins the endpoint to
//!                                       legacy v2 JSON framing)
//! repro fleet [N] [--peer-cores N] [--peer-im2col N] [--requests N] [--s52 F] [--dw F]
//!             [--gap-us G] [--max-inflight P] [--v2-peers M] [--models M]
//!             [--stream] [--images N] [--window W]
//!             [--trace-out FILE] [--metrics-addr A]
//!             [--kill-peer-after K] [--revive-after M]
//!                                       multi-machine demo: spawn N in-process TCP
//!                                       peers, front them with one remote-core pool,
//!                                       run a mixed trace through the fleet.
//!                                       --v2-peers M pins the first M peers to
//!                                       legacy wire v2 (mixed-protocol fleet: the
//!                                       front must negotiate per peer and stay
//!                                       bit-identical across both framings).
//!                                       --models M drives multi-tenant registry
//!                                       traffic over M models and exits non-zero
//!                                       unless the v4 weight store saw hits while
//!                                       every v2-pinned peer stayed cache-silent
//!                                       (incompatible with --kill-peer-after
//!                                       unless --stream is also given).
//!                                       --stream (needs --models) streams --images
//!                                       N whole-network images through the fleet,
//!                                       --window W in flight at once; exits
//!                                       non-zero unless every image's logits are
//!                                       bit-identical to the registry golden, the
//!                                       weight store saw hits after image 0, and
//!                                       cross-image overlap was observed. With
//!                                       --kill-peer-after K / --revive-after M the
//!                                       indexes are *image* numbers and the killed
//!                                       peer's in-flight layers fail over without
//!                                       losing any image.
//!                                       Chaos mode: --kill-peer-after K severs the
//!                                       last peer just before trace entry K (its
//!                                       port stays bound, connections drop);
//!                                       --revive-after M brings it back at entry M
//!                                       and the run then proves the revived peer
//!                                       serves traffic again. Exits non-zero unless
//!                                       every non-shed request succeeds.
//!                                       --trace-out FILE is the telemetry smoke: it
//!                                       exits non-zero unless the exported Chrome
//!                                       trace contains a complete span tree for
//!                                       every successfully answered request (or
//!                                       image, with --stream). --metrics-addr A
//!                                       additionally exits non-zero unless the
//!                                       scrape endpoint answered mid-run with
//!                                       non-zero counters.
//! repro artifacts                       list the AOT artifact registry
//! ```

use repro::coordinator::{CoordinatorConfig, Server};
use repro::hw::ip_core::{gops_mac, gops_psum};
use repro::hw::resource::{max_cores, render_table1, PAPER_TABLE1};
use repro::hw::waveform::{fig6_stimulus, WaveTrace};
use repro::hw::{AccumMode, IpCore, IpCoreConfig};
use repro::model::network::EdgeCnn;
use repro::model::trace::{generate, TraceConfig};
use repro::model::{LayerSpec, Tensor, S52};
use repro::paper;
use repro::telemetry::scrape::ScrapeServer;
use repro::telemetry::SpanSink;
use repro::util::cli::Args;
use repro::util::prng::Prng;
use std::sync::Arc;

const USAGE: &str = "usage: repro <waveform|table1|throughput|simulate|infer|serve|serve-tcp|fleet|artifacts|capacity|energy|mobilenet> [options]
run `repro help` or see rust/src/main.rs docs for per-command options";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(argv, &["vcd", "wrap8", "no-pipeline", "dma", "xla", "v2-only", "stream"])
        .map_err(|e| anyhow::anyhow!(e))?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "waveform" => cmd_waveform(&args),
        "table1" => cmd_table1(),
        "throughput" => cmd_throughput(&args),
        "simulate" => cmd_simulate(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "fleet" => cmd_fleet(&args),
        "artifacts" => cmd_artifacts(),
        "capacity" => cmd_capacity(&args),
        "energy" => cmd_energy(&args),
        "mobilenet" => cmd_mobilenet(&args),
        "serve-tcp" => cmd_serve_tcp(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_waveform(args: &Args) -> anyhow::Result<()> {
    let (spec, img, weights, bias) = fig6_stimulus();
    let mut trace = WaveTrace::fig6();
    let mut core = IpCore::new(IpCoreConfig {
        mode: AccumMode::Wrap8,
        ..Default::default()
    });
    let run = core.run_layer(&spec, &img, &weights, &bias, Some(&mut trace))?;
    println!("Fig. 6 reproduction — one computing core, 4 kernels, 5-wide ramp feature\n");
    print!("{}", trace.render_ascii());
    println!("\ncompute cycles: {} ({} windows x 8)", run.cycles.compute, run.cycles.compute / 8);
    if let Some(path) = args.get("vcd") {
        let period_ns = 1_000_000_000 / paper::FREQ_Z2_HZ;
        std::fs::write(path, trace.to_vcd(period_ns.max(1)))?;
        println!("VCD written to {path}");
    }
    Ok(())
}

fn cmd_table1() -> anyhow::Result<()> {
    println!("Table 1 (model) — synthesis estimates:\n");
    print!("{}", render_table1());
    println!("\nPaper's measured values:");
    for row in PAPER_TABLE1 {
        println!(
            "{:<22} {:>7}          {:>7}          {:>6.0} MHz",
            row.device, row.luts, row.ffs, row.fmax_mhz
        );
    }
    println!("\nMax IP cores per device (binding resource):");
    for d in repro::hw::device::TABLE1_DEVICES {
        let m = max_cores(&d);
        println!(
            "{:<22} by_lut={} by_ff={} -> {}",
            d.name, m.by_lut, m.by_ff, m.binding
        );
    }
    Ok(())
}

fn cmd_throughput(args: &Args) -> anyhow::Result<()> {
    let n_cores = args.get_usize("cores", 1).map_err(|e| anyhow::anyhow!(e))?;
    let mut rng = Prng::new(52);
    let spec = S52;
    let img = Tensor::from_vec(&[spec.c, spec.h, spec.w], rng.bytes_below(spec.c * spec.h * spec.w, 256));
    let wts = Tensor::from_vec(&[spec.k, spec.c, 3, 3], rng.bytes_below(spec.k * spec.c * 9, 256));
    let bias = vec![0i32; spec.k];
    let mut core = IpCore::new(IpCoreConfig::default());
    let run = core.run_layer(&spec, &img, &wts, &bias, None)?;
    let freq = paper::FREQ_Z2_HZ;
    let secs = run.cycles.compute as f64 / freq as f64;
    println!("§5.2 workload: image 224x224x8 (x) weights 8x3x3x8");
    println!("  psums            = {} (paper: 3,154,176)", spec.psums());
    println!("  compute cycles   = {} (paper: 1,577,088)", run.cycles.compute);
    println!("  time @112MHz     = {secs:.5} s (paper: 0.01408 s)");
    println!(
        "  single IP core   = {:.3} GOPS psum-accounting (paper: 0.224) | {:.3} GOPS true MAC ops",
        gops_psum(spec.psums(), run.cycles.compute, freq),
        gops_mac(spec.psums(), run.cycles.compute, freq)
    );
    println!(
        "  {} cores         = {:.3} GOPS psum-accounting (paper at 20: 4.48)",
        n_cores,
        gops_psum(spec.psums(), run.cycles.compute, freq) * n_cores as f64
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let c = args.get_usize("c", 8).map_err(|e| anyhow::anyhow!(e))?;
    let h = args.get_usize("h", 16).map_err(|e| anyhow::anyhow!(e))?;
    let w = args.get_usize("w", 16).map_err(|e| anyhow::anyhow!(e))?;
    let k = args.get_usize("k", 8).map_err(|e| anyhow::anyhow!(e))?;
    let seed = args.get_u64("seed", 1).map_err(|e| anyhow::anyhow!(e))?;
    let spec = LayerSpec::new(c, h, w, k);
    let mut rng = Prng::new(seed);
    let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
    let wts = Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256));
    let bias: Vec<i32> = (0..k).map(|_| rng.range_i64(0, 64) as i32).collect();
    let config = IpCoreConfig {
        mode: if args.flag("wrap8") { AccumMode::Wrap8 } else { AccumMode::I32 },
        pipelined: !args.flag("no-pipeline"),
        count_dma: args.flag("dma"),
        ..Default::default()
    };
    let mut core = IpCore::new(config);
    let run = core.run_layer(&spec, &img, &wts, &bias, None)?;
    println!("layer {}: {:?}", spec.name(), config);
    println!("  cycles: {:?}", run.cycles);
    println!("  phases: {:?}", run.phases);
    println!(
        "  gops(psum)={:.4} gops(mac)={:.4} @ {} MHz",
        gops_psum(spec.psums(), run.cycles.total, config.freq_hz),
        gops_mac(spec.psums(), run.cycles.total, config.freq_hz),
        config.freq_hz / 1_000_000
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> anyhow::Result<()> {
    let seed = args.get_u64("seed", 7).map_err(|e| anyhow::anyhow!(e))?;
    let net = EdgeCnn::new(42);
    let img = EdgeCnn::sample_input(seed, &net.specs()[0]);
    let golden = net.forward_golden(&img);
    let mut sched = repro::coordinator::CnnScheduler::new(IpCoreConfig::default(), net);
    let run = sched.infer(&img)?;
    println!("edge CNN inference (seed {seed}):");
    println!("  class={} logits[0..6]={:?}", run.class, &run.logits[..6]);
    println!(
        "  hw-sim == golden: {}",
        if run.logits == golden { "YES (bit-exact)" } else { "NO — numerics bug" }
    );
    println!(
        "  total cycles = {} ({} with per-layer DMA round-trip; §4.1 chaining saves {:.1}%)",
        run.total_cycles,
        run.total_cycles_dma_roundtrip,
        100.0 * (1.0 - run.total_cycles as f64 / run.total_cycles_dma_roundtrip as f64)
    );
    for rec in &run.layers {
        println!(
            "    {:<24} compute={:>8} dma_in={:>6} dma_out={:>6}",
            rec.name, rec.cycles.compute, rec.cycles.dma_in, rec.cycles.dma_out
        );
    }
    if args.flag("xla") {
        let mut rt = repro::runtime::XlaRuntime::with_default_registry()?;
        let params: Vec<(Tensor<u8>, Vec<i32>)> = sched
            .net
            .params
            .layers
            .iter()
            .map(|l| (l.weights.clone(), l.bias.clone()))
            .collect();
        let logits = rt.run_edge_cnn(&img, &params)?;
        let class = repro::model::network::argmax_f32(&logits);
        println!("  xla fused-CNN class={class} (platform {})", rt.platform());
    }
    Ok(())
}

/// `--bench-json PATH` (default `BENCH_serving.json`): the serving
/// trajectory in machine-readable form, for CI and benchmark history.
fn write_bench_json(args: &Args, report: &repro::coordinator::server::Report) -> anyhow::Result<()> {
    let path = args.get("bench-json").unwrap_or("BENCH_serving.json");
    std::fs::write(path, format!("{}\n", report.to_json().to_json()))?;
    println!("bench trajectory written to {path}");
    Ok(())
}

/// Shared serve/fleet front-pool construction: local workers plus any
/// comma-separated `--remote` peers. `cores == 0` means no local sim
/// cores (a pure remote fan-out front).
fn front_config(cores: usize, golden: usize, im2col: usize, remote: Option<&str>) -> anyhow::Result<CoordinatorConfig> {
    anyhow::ensure!(
        cores <= repro::paper::MAX_CORES_Z2,
        "core count {cores} outside the paper's 0..=20 deployment range"
    );
    let mut config = CoordinatorConfig::default()
        .with_golden_workers(golden)
        .with_im2col_workers(im2col);
    config.n_cores = cores;
    if let Some(peers) = remote {
        config = config.with_remote_peers(
            peers
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
        );
    }
    Ok(config)
}

/// `--trace-out FILE` / `--metrics-addr ADDR` (serve and fleet): build
/// the telemetry attachments the run asked for. A trace file implies a
/// span sink on the config; a metrics addr binds the scrape endpoint
/// now (port 0 resolves before the run) and prints where it landed.
fn telemetry_from_args(
    args: &Args,
    mut config: CoordinatorConfig,
) -> anyhow::Result<(CoordinatorConfig, Option<Arc<SpanSink>>, Option<Arc<ScrapeServer>>)> {
    let mut sink = None;
    if args.get("trace-out").is_some() {
        let s = Arc::new(SpanSink::new());
        config = config.with_trace(Arc::clone(&s));
        sink = Some(s);
    }
    let mut scrape = None;
    if let Some(addr) = args.get("metrics-addr") {
        let srv = Arc::new(ScrapeServer::bind(addr)?);
        println!(
            "metrics: Prometheus text exposition live on http://{}/metrics",
            srv.addr()
        );
        config = config.with_scrape(Arc::clone(&srv));
        scrape = Some(srv);
    }
    Ok((config, sink, scrape))
}

/// Export the span ring as Chrome trace-event JSON to `--trace-out`.
fn write_trace_out(args: &Args, sink: &Option<Arc<SpanSink>>) -> anyhow::Result<()> {
    if let (Some(path), Some(sink)) = (args.get("trace-out"), sink) {
        std::fs::write(path, sink.to_chrome_trace())?;
        println!(
            "chrome trace ({} spans, {} dropped to ring wrap) written to {path}",
            sink.snapshot().len(),
            sink.dropped()
        );
    }
    Ok(())
}

/// One HTTP GET against the scrape endpoint, body returned verbatim.
fn scrape_once(addr: std::net::SocketAddr) -> std::io::Result<String> {
    use std::io::{Read as _, Write as _};
    let mut s = std::net::TcpStream::connect_timeout(&addr, std::time::Duration::from_secs(2))?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    write!(s, "GET /metrics HTTP/1.1\r\nHost: repro\r\n\r\n")?;
    let mut body = String::new();
    s.read_to_string(&mut body)?;
    Ok(body)
}

/// Does a scrape body show work actually completed?
fn scrape_shows_progress(body: &str) -> bool {
    body.lines().any(|l| {
        l.strip_prefix("repro_completed_total ")
            .and_then(|v| v.trim().parse::<u64>().ok())
            .map_or(false, |n| n > 0)
    })
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cores = args.get_usize("cores", 4).map_err(|e| anyhow::anyhow!(e))?;
    let golden = args.get_usize("golden", 0).map_err(|e| anyhow::anyhow!(e))?;
    let im2col = args.get_usize("im2col", 0).map_err(|e| anyhow::anyhow!(e))?;
    let n = args.get_usize("requests", 64).map_err(|e| anyhow::anyhow!(e))?;
    let s52 = args.get_f64("s52", 0.1).map_err(|e| anyhow::anyhow!(e))?;
    let dw = args.get_f64("dw", 0.0).map_err(|e| anyhow::anyhow!(e))?;
    let models = args.get_usize("models", 0).map_err(|e| anyhow::anyhow!(e))?;
    let stream = args.flag("stream");
    let images = args.get_usize("images", 16).map_err(|e| anyhow::anyhow!(e))?;
    let window = args.get_usize("window", 4).map_err(|e| anyhow::anyhow!(e))?;
    let config = front_config(cores, golden, im2col, args.get("remote"))?
        .with_stream_window(window);
    let (config, sink, scrape) = telemetry_from_args(args, config)?;
    let mut server = Server::try_new(config)?;
    let report = if stream {
        // Whole-network streaming: each submission is (model, image),
        // walked layer-by-layer across the pool by the stream scheduler.
        anyhow::ensure!(
            models > 0,
            "--stream resolves whole-network submissions through the registry; give --models M"
        );
        anyhow::ensure!(images > 0, "--stream needs at least one image");
        let registry = repro::registry::ModelRegistry::builtin(models, 11);
        println!(
            "serve: streaming {images} images over {models} models (window {window})"
        );
        let (report, outcome) = server.run_stream_trace(&registry, images, 11, &mut |_| {});
        for (l, us) in outcome.mean_layer_latency_us.iter().enumerate() {
            println!("  layer[{l}] mean latency = {us}us");
        }
        for o in &outcome.images {
            anyhow::ensure!(
                o.error.is_none() && o.matches,
                "image {} diverged from the registry golden: {:?}",
                o.image,
                o.error
            );
        }
        println!(
            "stream OK: {} images bit-exact vs golden, {} overlap events, {} layer jobs",
            outcome.images.len(),
            outcome.overlap_events,
            outcome.n_layer_jobs
        );
        report
    } else if models > 0 {
        // Registry traffic: requests are (model, layer) submissions over
        // the multi-model registry instead of the synthetic shape trace.
        let registry = repro::registry::ModelRegistry::builtin(models, 11);
        println!(
            "serve: registry traffic over {models} models ({} distinct weight blobs)",
            registry.distinct_weight_hashes()
        );
        server.run_registry_trace(&registry, n, 0, 11)
    } else {
        let trace = generate(&TraceConfig {
            n,
            mean_gap_us: 0,
            s52_fraction: s52,
            depthwise_fraction: dw,
            seed: 11,
        });
        server.run_trace(&trace)
    };
    println!("{}", report.render());
    write_bench_json(args, &report)?;
    write_trace_out(args, &sink)?;
    if let Some(s) = &scrape {
        println!("metrics: {} scrapes answered", s.scrapes());
        s.stop();
    }
    server.shutdown();
    Ok(())
}

/// The multi-machine demo and chaos harness, runnable in CI: spawn N
/// in-process wire-v4 TCP peers, front them with one pool of
/// `RemoteBackend` workers, and push a mixed trace through the fleet —
/// optionally killing (and reviving) the last peer mid-trace. Exits
/// non-zero unless every non-shed request succeeds; with a revive, it
/// additionally proves the revived peer serves traffic again. With
/// `--models M` the trace is multi-tenant registry traffic and the run
/// additionally proves the weight store saw hits while every v2-pinned
/// peer stayed cache-silent.
fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    use repro::coordinator::tcp::TcpServer;
    use std::sync::atomic::Ordering;
    let n = match args.positional.get(1) {
        None => 2,
        Some(s) => s
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("fleet expects a peer count, e.g. `repro fleet 2`"))?,
    };
    anyhow::ensure!(n >= 1, "fleet needs at least one peer");
    let peer_cores = args.get_usize("peer-cores", 2).map_err(|e| anyhow::anyhow!(e))?;
    let peer_im2col = args.get_usize("peer-im2col", 0).map_err(|e| anyhow::anyhow!(e))?;
    let cores = args.get_usize("cores", 0).map_err(|e| anyhow::anyhow!(e))?;
    let requests = args.get_usize("requests", 64).map_err(|e| anyhow::anyhow!(e))?;
    let s52 = args.get_f64("s52", 0.05).map_err(|e| anyhow::anyhow!(e))?;
    let dw = args.get_f64("dw", 0.25).map_err(|e| anyhow::anyhow!(e))?;
    let gap_us = args.get_u64("gap-us", 0).map_err(|e| anyhow::anyhow!(e))?;
    let opt_entry = |key: &str| -> anyhow::Result<Option<usize>> {
        match args.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects a trace-entry index")),
        }
    };
    let v2_peers = args.get_usize("v2-peers", 0).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(
        v2_peers <= n,
        "--v2-peers {v2_peers} exceeds the fleet size {n}"
    );
    let models = args.get_usize("models", 0).map_err(|e| anyhow::anyhow!(e))?;
    let stream = args.flag("stream");
    let images = args.get_usize("images", 16).map_err(|e| anyhow::anyhow!(e))?;
    let window = args.get_usize("window", 4).map_err(|e| anyhow::anyhow!(e))?;
    let kill_after = opt_entry("kill-peer-after")?;
    let revive_after = opt_entry("revive-after")?;
    if stream {
        anyhow::ensure!(
            models > 0,
            "--stream resolves whole-network submissions through the registry; give --models M"
        );
        anyhow::ensure!(images > 0, "--stream needs at least one image");
    } else {
        anyhow::ensure!(
            models == 0 || kill_after.is_none(),
            "--models cannot be combined with --kill-peer-after (chaos mode drives the \
             synthetic trace; streaming chaos needs --stream)"
        );
    }
    // In stream mode the chaos indexes count *images*, not trace entries.
    let chaos_span = if stream { images } else { requests };
    if let Some(k) = kill_after {
        anyhow::ensure!(n >= 2, "chaos mode needs at least two peers to fail over between");
        anyhow::ensure!(k < chaos_span, "--kill-peer-after {k} is past the end of the run");
        if let Some(m) = revive_after {
            anyhow::ensure!(m > k, "--revive-after must come after --kill-peer-after");
        }
    } else {
        anyhow::ensure!(
            revive_after.is_none(),
            "--revive-after without --kill-peer-after"
        );
    }

    let mut peers = Vec::new();
    for i in 0..n {
        // Same constructor as the front: --peer-cores 0 with im2col
        // workers is a legitimate host-only peer, and a fully empty
        // peer errors cleanly instead of panicking. The first
        // --v2-peers endpoints are pinned to legacy v2 JSON framing so
        // the front has to negotiate per peer.
        let mut pc = front_config(peer_cores, 0, peer_im2col, None)?;
        if i < v2_peers {
            pc = pc.with_wire_v2_only();
        }
        peers.push(TcpServer::start("127.0.0.1:0", pc)?);
    }
    let peer_addrs: Vec<String> = peers.iter().map(|p| p.addr.to_string()).collect();
    println!(
        "fleet: {n} in-process wire-v4 peers ({peer_cores} sim cores{} each{}) at {}",
        if peer_im2col > 0 {
            format!(" + {peer_im2col} im2col workers")
        } else {
            String::new()
        },
        if v2_peers > 0 {
            format!("; first {v2_peers} pinned to legacy wire v2")
        } else {
            String::new()
        },
        peer_addrs.join(", ")
    );

    let mut config = front_config(cores, 0, 0, None)?.with_stream_window(window);
    config = config.with_remote_peers(peer_addrs);
    if let Some(m) = args.get("max-inflight") {
        config.max_inflight_psums = Some(
            m.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--max-inflight expects a PSUM budget"))?,
        );
    }
    let (config, sink, scrape) = telemetry_from_args(args, config)?;
    let mut front = Server::try_new(config)?;

    // Mid-run scrape checker: polls the metrics endpoint while the
    // trace runs, until a snapshot shows live (non-zero) completion
    // counters — the proof the endpoint serves *during* the run, not
    // just after it.
    let scrape_hit = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let checker_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let checker = scrape.as_ref().map(|s| {
        let addr = s.addr();
        let hit = Arc::clone(&scrape_hit);
        let stop = Arc::clone(&checker_stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                if let Ok(body) = scrape_once(addr) {
                    if scrape_shows_progress(&body) {
                        hit.store(true, Ordering::Relaxed);
                        return;
                    }
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        })
    });
    let mut stream_outcome = None;
    let report = if stream {
        // Whole-network streaming across the fleet: every image's layer
        // chain hops across the peers (weights riding the v4 store),
        // with the chaos hooks firing on *image* admission.
        let registry = repro::registry::ModelRegistry::builtin(models, 17);
        println!(
            "fleet: streaming {images} images over {models} models (window {window}, {} distinct weight blobs)",
            registry.distinct_weight_hashes()
        );
        let (report, outcome) = front.run_stream_trace(&registry, images, 17, &mut |i| {
            if kill_after == Some(i) {
                println!("chaos: killing peer {} before image {i}", n - 1);
                peers[n - 1].set_down(true);
            }
            if revive_after == Some(i) {
                println!("chaos: reviving peer {} before image {i}", n - 1);
                peers[n - 1].set_down(false);
            }
        });
        stream_outcome = Some(outcome);
        report
    } else if models > 0 {
        // Multi-tenant registry traffic: every request is a (model,
        // layer) submission, so repeated layers exercise the v4 weight
        // store across the fleet (chaos flags are rejected above).
        let registry = repro::registry::ModelRegistry::builtin(models, 17);
        println!(
            "fleet: registry traffic over {models} models ({} distinct weight blobs)",
            registry.distinct_weight_hashes()
        );
        front.run_registry_trace(&registry, requests, gap_us, 17)
    } else {
        let trace = generate(&TraceConfig {
            n: requests,
            mean_gap_us: gap_us,
            s52_fraction: s52,
            depthwise_fraction: dw,
            seed: 17,
        });
        // The chaos target is always the *last* peer: with default
        // flags it never serves alone, so siblings exist to fail over
        // onto.
        front.run_trace_with(&trace, &mut |i| {
            if kill_after == Some(i) {
                println!("chaos: killing peer {} before entry {i}", n - 1);
                peers[n - 1].set_down(true);
            }
            if revive_after == Some(i) {
                println!("chaos: reviving peer {} before entry {i}", n - 1);
                peers[n - 1].set_down(false);
            }
        })
    };
    println!("{}", report.render());
    write_bench_json(args, &report)?;

    // Telemetry contracts are checked against the *main* run, before
    // any recovery waves reuse the front (which would re-mint trace
    // ids and double up request roots in the ring).
    let scrapes_mid_run = scrape.as_ref().map(|s| s.scrapes()).unwrap_or(0);
    if let Some(checker) = checker {
        // A very fast run gets a short grace window for its last poll.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while !scrape_hit.load(Ordering::Relaxed) && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        checker_stop.store(true, Ordering::Relaxed);
        checker.join().ok();
    }
    if let Some(s) = &scrape {
        anyhow::ensure!(
            scrapes_mid_run > 0,
            "metrics endpoint was never scraped while the run was live"
        );
        anyhow::ensure!(
            scrape_hit.load(Ordering::Relaxed),
            "the scrape endpoint never showed non-zero completion counters"
        );
        println!(
            "metrics OK: {} scrapes answered ({scrapes_mid_run} mid-run), counters live",
            s.scrapes()
        );
    }
    if let Some(sink) = &sink {
        let check = repro::telemetry::validate_coverage(&sink.snapshot())
            .map_err(|e| anyhow::anyhow!("trace validation failed: {e}"))?;
        let expected_roots = if stream {
            stream_outcome
                .as_ref()
                .map(|o| o.images.len())
                .unwrap_or(images)
        } else {
            // Shed entries never minted an id; errored ones recorded no
            // spans. Every other request must have a complete tree.
            report.n_requests.saturating_sub(report.n_errors)
        };
        anyhow::ensure!(
            check.roots == expected_roots,
            "trace holds {} complete span trees for {expected_roots} answered requests",
            check.roots
        );
        println!(
            "trace OK: {} complete span trees, worst per-request coverage {:.1}%",
            check.roots,
            check.worst_coverage * 100.0
        );
    }
    write_trace_out(args, &sink)?;
    if stream {
        // Streaming runs must decompose latency per layer hop.
        let layer_obs: u64 = front
            .stage_counts()
            .iter()
            .filter(|(name, _)| name.starts_with("layer"))
            .map(|&(_, c)| c)
            .sum();
        anyhow::ensure!(
            layer_obs > 0,
            "streaming run recorded no per-layer stage histograms"
        );
        println!("stage histograms OK: {layer_obs} per-layer observations");
    }
    let served_remote = report
        .backend_mix
        .iter()
        .any(|(name, _)| name.starts_with("remote@"));

    // With a revive, prove recovery end to end: keep pushing small
    // traffic waves until the revived peer answers some of them (the
    // front's health probe needs a beat to re-dial and flip it back).
    let mut revived_served = revive_after.is_none();
    if revive_after.is_some() {
        // A revive index past the trace end never fired during the run;
        // apply it now (idempotent otherwise) so recovery is exercised.
        peers[n - 1].set_down(false);
        let before = peers[n - 1].metrics().completed.load(Ordering::Relaxed);
        for _wave in 0..50 {
            std::thread::sleep(std::time::Duration::from_millis(100));
            let wave = generate(&TraceConfig {
                n: 4,
                mean_gap_us: 0,
                s52_fraction: 0.0,
                depthwise_fraction: 0.0,
                seed: 99,
            });
            let r = front.run_trace(&wave);
            anyhow::ensure!(r.n_errors == 0, "recovery wave had {} job errors", r.n_errors);
            if peers[n - 1].metrics().completed.load(Ordering::Relaxed) > before {
                revived_served = true;
                break;
            }
        }
        println!(
            "chaos: revived peer served traffic again: {revived_served}"
        );
    }

    // Read per-peer counters before teardown consumes the servers
    // (`TcpServer::stop` takes the server by value).
    let v2_served: u64 = peers[..v2_peers]
        .iter()
        .map(|p| p.metrics().completed.load(Ordering::Relaxed))
        .sum();
    let v2_cache_traffic: u64 = peers[..v2_peers]
        .iter()
        .map(|p| {
            let m = p.metrics();
            m.weight_hits.load(Ordering::Relaxed) + m.weight_misses.load(Ordering::Relaxed)
        })
        .sum();
    front.shutdown();
    for p in peers {
        p.stop();
    }
    if let Some(s) = &scrape {
        s.stop();
    }
    anyhow::ensure!(
        report.n_errors == 0,
        "fleet run had {} job errors",
        report.n_errors
    );
    anyhow::ensure!(
        served_remote,
        "no remote worker served traffic: {:?}",
        report.backend_mix
    );
    if v2_peers > 0 {
        // Mixed-protocol contract: the v2-pinned peers must actually
        // have served traffic over the JSON fallback, not just sat in
        // the pool while v4 siblings took everything.
        anyhow::ensure!(
            v2_served > 0,
            "no v2-pinned peer served any traffic in the mixed fleet"
        );
        println!("mixed fleet OK: v2-pinned peers served {v2_served} jobs over JSON framing");
    }
    if models > 0 {
        // Multi-tenant contract: repeated layers must actually hit the
        // weight store, and v2-pinned peers must never see any cache
        // traffic (they negotiated a framing with no weight hashes).
        anyhow::ensure!(
            report.n_weight_hits > 0,
            "multi-tenant fleet never hit the weight store (hits={}, misses={})",
            report.n_weight_hits,
            report.n_weight_misses
        );
        anyhow::ensure!(
            v2_cache_traffic == 0,
            "a v2-pinned peer saw weight-cache traffic ({v2_cache_traffic} events)"
        );
        println!(
            "weight store OK: {} hits / {} misses, {} weight bytes kept off the wire",
            report.n_weight_hits, report.n_weight_misses, report.wire_weight_bytes_saved
        );
    }
    if let Some(out) = &stream_outcome {
        // Streaming contract: no image lost, every image bit-exact
        // against the registry's own golden forward, and the pipelining
        // demonstrably real (overlap observed, not just configured).
        for o in &out.images {
            anyhow::ensure!(
                o.error.is_none() && o.matches,
                "image {} diverged from the registry golden: {:?}",
                o.image,
                o.error
            );
        }
        if window > 1 && images > 1 {
            anyhow::ensure!(
                out.overlap_events > 0,
                "no cross-image overlap observed with window {window}"
            );
        }
        for (l, us) in out.mean_layer_latency_us.iter().enumerate() {
            println!("  layer[{l}] mean latency = {us}us");
        }
        println!(
            "stream OK: {} images bit-exact vs golden at {:.1} images/s, {} overlap events, {} layer jobs ({} resubmitted)",
            out.images.len(),
            report.images_per_sec,
            out.overlap_events,
            out.n_layer_jobs,
            out.n_resubmits
        );
    }
    anyhow::ensure!(
        revived_served,
        "revived peer never served traffic again"
    );
    if kill_after.is_some() {
        println!(
            "fleet OK under chaos: every non-shed request answered (shed={}, retried={}, recovered_peers={})",
            report.n_shed, report.n_retried, report.n_recovered_peers
        );
    } else {
        println!("fleet OK: every request answered; remote workers in the mix");
    }
    Ok(())
}

fn cmd_capacity(args: &Args) -> anyhow::Result<()> {
    use repro::hw::capacity::fits;
    let c = args.get_usize("c", 8).map_err(|e| anyhow::anyhow!(e))?;
    let h = args.get_usize("h", 224).map_err(|e| anyhow::anyhow!(e))?;
    let w = args.get_usize("w", 224).map_err(|e| anyhow::anyhow!(e))?;
    let k = args.get_usize("k", 8).map_err(|e| anyhow::anyhow!(e))?;
    let spec = LayerSpec::new(c, h, w, k);
    println!("BRAM fit for {} (20% of blocks reserved):", spec.name());
    for dev in repro::hw::device::TABLE1_DEVICES {
        for (label, mode) in [("wrap8", AccumMode::Wrap8), ("i32", AccumMode::I32)] {
            let r = fits(&spec, &dev, mode, 0.2);
            println!(
                "  {:<22} {label:<6} {:>5}/{:<4} blocks fits={} {}",
                dev.name,
                r.demand.blocks,
                r.device_blocks,
                r.fits,
                r.max_strip_rows
                    .map(|n| format!("strip<={n} rows"))
                    .unwrap_or_default()
            );
        }
    }
    Ok(())
}

fn cmd_energy(args: &Args) -> anyhow::Result<()> {
    use repro::hw::power::{estimate_layer, model_for};
    let c = args.get_usize("c", 8).map_err(|e| anyhow::anyhow!(e))?;
    let h = args.get_usize("h", 16).map_err(|e| anyhow::anyhow!(e))?;
    let w = args.get_usize("w", 16).map_err(|e| anyhow::anyhow!(e))?;
    let k = args.get_usize("k", 8).map_err(|e| anyhow::anyhow!(e))?;
    let spec = LayerSpec::new(c, h, w, k);
    let mut rng = Prng::new(1);
    let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
    let wts = Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256));
    let run = IpCore::new(IpCoreConfig::default()).run_layer(&spec, &img, &wts, &vec![0; k], None)?;
    println!("energy estimate for {} (activity model, hw::power):", spec.name());
    for dev in repro::hw::device::TABLE1_DEVICES {
        let e = estimate_layer(&spec, &run.cycles, &run.dma, &model_for(&dev));
        println!(
            "  {:<22} mac={:.1}nJ bram={:.1}nJ dma={:.1}nJ idle={:.1}nJ total={:.1}nJ ({:.0} psums/uJ)",
            dev.name,
            e.mac_nj,
            e.bram_nj,
            e.dma_nj,
            e.idle_nj,
            e.total_nj(),
            e.psums_per_uj(spec.psums())
        );
    }
    Ok(())
}

fn cmd_mobilenet(args: &Args) -> anyhow::Result<()> {
    use repro::model::mobilenet::{mobilenet_lite_specs, MobileNetLite};
    let seed = args.get_u64("seed", 7).map_err(|e| anyhow::anyhow!(e))?;
    let net = MobileNetLite::new(42);
    let img = MobileNetLite::sample_input(seed, &mobilenet_lite_specs()[0]);
    let golden = net.forward_golden(&img);
    let mut core = IpCore::new(IpCoreConfig::default());
    let (sim, cycles, util) = net.infer_sim(&mut core, &img)?;
    println!("mobilenet-lite (depthwise-separable) on the paper's IP core:");
    println!(
        "  sim == golden: {}",
        if sim.data() == golden.data() { "YES (bit-exact)" } else { "NO" }
    );
    println!(
        "  {} compute cycles = {:.3} ms @112MHz; effective MAC utilisation {:.1}% \
         (vs 100% for standard conv — the §4.1 MobileNet motivation doesn't survive \
         the fixed dataflow; see hw::depthwise docs)",
        cycles,
        cycles as f64 / paper::FREQ_Z2_HZ as f64 * 1e3,
        util * 100.0
    );
    Ok(())
}

fn cmd_serve_tcp(args: &Args) -> anyhow::Result<()> {
    use repro::coordinator::tcp::TcpServer;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7420");
    let cores = args.get_usize("cores", 4).map_err(|e| anyhow::anyhow!(e))?;
    let golden = args.get_usize("golden", 0).map_err(|e| anyhow::anyhow!(e))?;
    let im2col = args.get_usize("im2col", 0).map_err(|e| anyhow::anyhow!(e))?;
    let mut config = front_config(cores, golden, im2col, args.get("remote"))?;
    if args.flag("v2-only") {
        config = config.with_wire_v2_only();
    }
    let server = TcpServer::start(addr, config)?;
    if args.flag("v2-only") {
        println!(
            "serving legacy wire protocol v2 (newline-delimited JSON) on {} \
             ({cores} sim cores, {golden} golden, {im2col} im2col workers)",
            server.addr
        );
    } else {
        println!(
            "serving wire protocol v4 (binary tensor frames + content-addressed weight \
             store) on {} ({cores} sim cores, {golden} golden, {im2col} im2col workers)",
            server.addr
        );
    }
    println!(r#"try: echo '{{"id":1,"spec":{{"c":8,"h":16,"w":16,"k":8}},"seed":42}}' | nc {} {}"#,
        server.addr.ip(), server.addr.port());
    println!("ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let reg = repro::runtime::ArtifactRegistry::load_default()?;
    println!("artifact registry at {}:", reg.dir.display());
    for (name, v) in &reg.variants {
        println!(
            "  {:<26} kind={:<10} file={:<30} out={:?}",
            name, v.kind, v.file, v.output
        );
    }
    Ok(())
}
