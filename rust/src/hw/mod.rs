//! Cycle-accurate simulator of the paper's FPGA IP core.
//!
//! This is the hardware substitution (DESIGN.md §2): no FPGA is
//! available, so the Verilog design is modelled at the level its claims
//! live at — *exact PSUM schedules* (Fig. 6) and *exact cycle counts ×
//! frequency* (§5.2), plus an analytic resource model for Table 1.
//!
//! Module map (paper section → module):
//! * §4.1 BRAM organisation → [`bram`] (BMG model, image/weight/output
//!   sets with the 4-way channel and interleaved kernel split)
//! * §3 DMA / AXI4 → [`dma`]
//! * §4.2 PCORE (9 MACs + adder tree) → [`mac`], [`pcore`]
//! * §4.2 loaders (weight-stationary) → [`loader`]
//! * §4.2 multi-kernel computing core → [`compute_core`]
//! * §4.2 multi-channel architecture + controller → [`controller`],
//!   [`ip_core`]
//! * §4.2 pipeline → [`pipeline`]
//! * Fig. 6 → [`waveform`] (signal tracing + VCD export)
//! * Table 1 → [`device`], [`resource`]
//!
//! Serving code does not drive [`IpCore`] directly any more: the
//! simulator is one [`crate::backend::ConvBackend`] implementation
//! (`backend::SimBackend`), which also routes [`depthwise`] through the
//! same entry point as standard layers. Direct use remains for the
//! experiment drivers (waveforms, tiling, resource/power models).

pub mod bram;
pub mod capacity;
pub mod compute_core;
pub mod controller;
pub mod depthwise;
pub mod device;
pub mod dma;
pub mod ip_core;
pub mod loader;
pub mod mac;
pub mod pcore;
pub mod pipeline;
pub mod power;
pub mod resource;
pub mod stepped;
pub mod waveform;

pub use ip_core::{IpCore, IpCoreConfig, LayerRun};

/// Accumulator semantics (DESIGN.md §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AccumMode {
    /// Bit-exact Fig. 6 silicon: PSUMs wrap modulo 256.
    Wrap8,
    /// Production mode: 32-bit accumulation of u8 products.
    I32,
}
