//! Fig. 6 reproduction (experiment F6): renders the exact waveform of
//! the paper's simulation figure from the cycle-accurate computing-core
//! model, checks every psum against the published values, and writes a
//! GTKWave-loadable VCD.
//!
//! ```bash
//! cargo run --release --example waveform_repro [out.vcd]
//! ```

use repro::hw::waveform::{fig6_stimulus, WaveTrace, FIG6_PSUMS};
use repro::hw::{AccumMode, IpCore, IpCoreConfig};

fn main() -> anyhow::Result<()> {
    let (spec, img, weights, bias) = fig6_stimulus();
    let mut trace = WaveTrace::fig6();
    let mut core = IpCore::new(IpCoreConfig {
        mode: AccumMode::Wrap8,
        ..Default::default()
    });
    let run = core.run_layer(&spec, &img, &weights, &bias, Some(&mut trace))?;

    println!("=== Fig. 6: one computing core, 4 kernels over a 5-wide ramp feature ===\n");
    print!("{}", trace.render_ascii());

    // Verify against the figure, psum by psum.
    let mut mismatches = 0;
    for (j, expected) in FIG6_PSUMS.iter().enumerate() {
        let got: Vec<u8> = trace
            .series(&format!("psum_{j}"))
            .unwrap()
            .iter()
            .map(|s| u8::from_str_radix(s, 16).unwrap())
            .collect();
        let ok = got == expected;
        if !ok {
            mismatches += 1;
        }
        println!(
            "psum_{j}: {}",
            if ok { "matches the paper's figure bit-exactly" } else { "MISMATCH" }
        );
    }
    anyhow::ensure!(mismatches == 0, "{mismatches} psum rows diverge from Fig. 6");

    println!(
        "\n{} windows x 8 cycles = {} compute cycles (paper: 8 cycles per 4 psums per core)",
        run.cycles.compute / 8,
        run.cycles.compute
    );

    let out = std::env::args().nth(1).unwrap_or_else(|| "fig6.vcd".into());
    std::fs::write(&out, trace.to_vcd(9))?; // ~112 MHz -> 8.93ns period
    println!("VCD written to {out} (open with GTKWave)");
    Ok(())
}
