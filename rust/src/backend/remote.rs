//! [`ConvBackend`] over a persistent TCP connection to a wire-protocol
//! v2 peer ([`crate::coordinator::tcp`]) — the remote-core backend that
//! turns N TCP-served machines into one heterogeneous pool.
//!
//! The paper scales by replicating its IP core on one board; this
//! backend scales past the board: each [`RemoteBackend`] dials one
//! `TcpServer` peer, reads its `hello` capability advertisement (which
//! kinds it serves, in which accumulator mode, behind how many
//! workers), and then presents the whole remote machine to the local
//! pool as one more capability-masked, cost-weighted worker — exactly
//! the host-side scheduler shape the FPGA-CNN survey literature
//! prescribes for multi-accelerator deployments.
//!
//! Per job, the backend ships the explicit tensors across the socket
//! with `"full_output":true` and reconstructs the reply tensor, so the
//! parity contract holds end-to-end over the wire: bit-identical i32
//! outputs for standard, depthwise and pointwise-as-3×3 jobs
//! (`rust/tests/backend_parity.rs` runs it as just another backend).
//!
//! Failure semantics: a dropped peer **fails its in-flight job and
//! drops the connection**; the next job redials (re-running the
//! handshake), and the pool's failover retry re-enqueues the failed job
//! on a capable sibling. The `weights_resident` DMA discount does not
//! cross the wire: every remote job pays its own transfer.
//!
//! **Health:** each backend runs a background probe thread
//! ([`HEALTH_PROBE_INTERVAL`]) that re-dials the peer on its own
//! short-lived connection, checks the fresh `hello` is no narrower than
//! the pool's routing snapshot, and — when the peer advertises the
//! `ping` feature in its hello — round-trips a `ping` control frame.
//! The result lands in a shared [`WorkerHealth`] flag the dispatcher
//! reads: a dead peer is routed *around* while healthy siblings exist
//! (degraded capacity, not lost correctness), and a revived peer
//! rejoins routing as soon as one probe succeeds — no job has to fail
//! to discover it came back.

use super::{
    BackendRun, Capability, ConvBackend, CostModel, JobKind, JobPayload, RemotePeerClass,
    WorkerHealth,
};
use crate::coordinator::tcp::{read_line_capped, LineRead, MAX_LINE_BYTES, PROTO_VERSION};
use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::{Tensor, QUICKSTART};
use crate::util::json::Json;
use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Hard ceiling on waiting for one reply. A peer that stalls past this
/// fails the job (and the connection) instead of hanging a pool worker
/// forever; simulated jobs answer in milliseconds, so thirty seconds
/// only ever trips on a genuinely wedged peer. Writes carry the same
/// bound, so a peer that stops reading can't park a worker either.
pub const REMOTE_REPLY_TIMEOUT: Duration = Duration::from_secs(30);

/// Ceiling on (re)dialling a peer. A black-holed peer (powered off,
/// packets dropped without RST) must fail each redialling job after
/// seconds, not stall the pool worker for the kernel's multi-minute
/// default connect timeout.
pub const REMOTE_CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// How often the background health probe re-validates the peer
/// ([`RemoteBackend::connect`] uses this; tests and the chaos harness
/// shorten it via [`RemoteBackend::connect_with_probe`]).
pub const HEALTH_PROBE_INTERVAL: Duration = Duration::from_millis(250);

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

/// What the peer's `hello` advertised.
#[derive(Clone, Copy, Debug)]
struct PeerInfo {
    standard: bool,
    depthwise: bool,
    pointwise: bool,
    /// All I32-capable workers behind the peer (the capability gate).
    workers: u64,
    /// The fastest compute tier among those workers — what
    /// [`CostModel::Remote`] prices the peer's compute as.
    class: RemotePeerClass,
    /// Peer advertised the `ping` control frame in its hello (feature
    /// negotiation — plain v2 peers lack the flag and are never pinged).
    ping: bool,
}

/// The capability flags routing snapshotted at construction; the probe
/// treats a peer that comes back narrower than this as unhealthy.
#[derive(Clone, Copy)]
struct CapSnapshot {
    standard: bool,
    depthwise: bool,
    pointwise: bool,
}

impl CapSnapshot {
    fn covered_by(&self, fresh: &PeerInfo) -> bool {
        (!self.standard || fresh.standard)
            && (!self.depthwise || fresh.depthwise)
            && (!self.pointwise || fresh.pointwise)
    }
}

/// One remote machine as a pool worker.
pub struct RemoteBackend {
    addr: String,
    /// Leaked once per constructed backend so worker names stay
    /// `&'static str` like every other backend's.
    name: &'static str,
    peer: PeerInfo,
    conn: Option<Conn>,
    next_id: u64,
    /// Shared with the dispatcher (via [`ConvBackend::health`]) and the
    /// probe thread.
    health: Arc<WorkerHealth>,
    probe_stop: Arc<AtomicBool>,
    probe: Option<JoinHandle<()>>,
}

fn parse_hello(line: &str) -> Result<PeerInfo, String> {
    let j = Json::parse(line.trim()).map_err(|e| format!("malformed hello: {e}"))?;
    let h = j
        .get(&["hello"])
        .ok_or("first frame from peer is not a hello")?;
    let proto = h.get(&["proto"]).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    if proto != PROTO_VERSION {
        return Err(format!(
            "peer speaks wire protocol {proto}, this backend needs {PROTO_VERSION}"
        ));
    }
    let workers = h
        .get(&["workers"])
        .and_then(Json::as_arr)
        .ok_or("hello.workers missing")?;
    let mut info = PeerInfo {
        standard: false,
        depthwise: false,
        pointwise: false,
        workers: 0,
        class: RemotePeerClass::HostMacs,
        // Feature negotiation rides on the hello: peers that can answer
        // `ping` control frames say so; plain v2 peers simply lack the
        // flag and are never sent one.
        ping: h.get(&["ping"]).and_then(Json::as_bool).unwrap_or(false),
    };
    let mut classes: Vec<RemotePeerClass> = Vec::new();
    for w in workers {
        // The wire serves I32 production traffic only; wrap-8 silicon
        // on the peer can never answer us, so it doesn't count.
        if w.get(&["accum"]).and_then(Json::as_str) != Some("i32") {
            continue;
        }
        info.workers += 1;
        let flag = |k: &str| w.get(&[k]).and_then(Json::as_bool).unwrap_or(false);
        info.standard |= flag("standard");
        info.depthwise |= flag("depthwise");
        info.pointwise |= flag("pointwise");
        // Missing `model` tags price conservatively (host loops).
        classes.push(
            w.get(&["model"])
                .and_then(Json::as_str)
                .map(RemotePeerClass::from_tag)
                .unwrap_or(RemotePeerClass::HostMacs),
        );
    }
    if info.workers == 0 {
        return Err("peer advertises no i32-capable workers".into());
    }
    // Price the peer by its fastest advertised tier (cheapest local
    // reference-job quote).
    info.class = classes
        .into_iter()
        .min_by_key(|c| c.model().cost(&QUICKSTART, JobKind::Standard))
        .expect("workers > 0 implies at least one class");
    Ok(info)
}

fn dial(addr: &str) -> anyhow::Result<(Conn, PeerInfo)> {
    // Try every resolved address (std's connect semantics): dual-stack
    // hostnames must not fail just because the first family is dead.
    let mut last_err: Option<std::io::Error> = None;
    let mut stream: Option<TcpStream> = None;
    for sock_addr in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sock_addr, REMOTE_CONNECT_TIMEOUT) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(e) => last_err = Some(e),
        }
    }
    let stream = match stream {
        Some(s) => s,
        None => match last_err {
            Some(e) => return Err(anyhow::anyhow!("{addr}: connect failed: {e}")),
            None => return Err(anyhow::anyhow!("{addr}: resolved to no address")),
        },
    };
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(REMOTE_REPLY_TIMEOUT))?;
    stream.set_write_timeout(Some(REMOTE_REPLY_TIMEOUT))?;
    let writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    match read_line_capped(&mut reader, &mut buf, MAX_LINE_BYTES)? {
        LineRead::Eof => anyhow::bail!("{addr}: peer closed before sending a hello"),
        LineRead::Line => {}
    }
    let line = String::from_utf8_lossy(&buf);
    let peer = parse_hello(&line).map_err(|e| anyhow::anyhow!("{addr}: {e}"))?;
    Ok((Conn { writer, reader }, peer))
}

fn request_json(id: u64, job: &JobPayload) -> Json {
    let mut spec = vec![
        ("c", Json::num(job.spec.c as f64)),
        ("h", Json::num(job.spec.h as f64)),
        ("w", Json::num(job.spec.w as f64)),
        ("k", Json::num(job.spec.k as f64)),
    ];
    if job.spec.relu {
        spec.push(("relu", Json::Bool(true)));
    }
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("kind", Json::str(job.kind.tag())),
        ("spec", Json::obj(spec)),
        ("img", Json::arr_u64(job.img.data().iter().map(|&v| v as u64))),
        (
            "weights",
            Json::arr_u64(job.weights.data().iter().map(|&v| v as u64)),
        ),
        ("bias", Json::arr_i64(job.bias.iter().map(|&v| v as i64))),
        ("full_output", Json::Bool(true)),
    ])
}

fn expected_shape(job: &JobPayload) -> Vec<usize> {
    let (oh, ow) = (job.spec.conv_oh(), job.spec.conv_ow());
    match job.kind {
        JobKind::Depthwise => vec![job.spec.c, oh, ow],
        JobKind::Standard | JobKind::PointwiseAs3x3 => vec![job.spec.k, oh, ow],
    }
}

/// One health probe: fresh dial, hello validation against the routing
/// snapshot, and — when the peer negotiated it — a `ping` round trip.
/// Runs on its own short-lived connection so it never desyncs the job
/// stream.
fn probe_once(addr: &str, snapshot: CapSnapshot) -> bool {
    let Ok((mut conn, fresh)) = dial(addr) else {
        return false;
    };
    if !snapshot.covered_by(&fresh) {
        // The peer restarted narrower than the pool's routing snapshot:
        // jobs routed by the old mask would bounce — treat as down.
        return false;
    }
    if !fresh.ping {
        // Plain v2 peer: the hello round trip itself is the probe.
        return true;
    }
    if writeln!(conn.writer, "{}", Json::obj(vec![("ping", Json::num(1.0))]).to_json()).is_err() {
        return false;
    }
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match read_line_capped(&mut conn.reader, &mut buf, MAX_LINE_BYTES) {
            Ok(LineRead::Line) => {}
            _ => return false,
        }
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(trimmed) else {
            return false;
        };
        if j.get(&["hello"]).is_some() {
            continue; // stray greeting; keep draining
        }
        return j.get(&["pong"]).and_then(Json::as_f64).is_some();
    }
}

fn spawn_probe(
    addr: String,
    snapshot: CapSnapshot,
    health: Arc<WorkerHealth>,
    stop: Arc<AtomicBool>,
    interval: Duration,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("probe-{addr}"))
        .spawn(move || {
            // Sleep in short ticks so Drop never waits a full interval
            // to join this thread.
            let tick = Duration::from_millis(25).min(interval).max(Duration::from_millis(1));
            let mut slept = Duration::ZERO;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(tick);
                slept += tick;
                if slept < interval {
                    continue;
                }
                slept = Duration::ZERO;
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                health.set_healthy(probe_once(&addr, snapshot));
            }
        })
        .expect("spawn remote health probe")
}

impl RemoteBackend {
    /// Dial `addr` (`host:port`) and perform the v2 handshake. Errors
    /// when the peer is unreachable, greets with anything but a valid
    /// v2 `hello`, or fronts no I32-capable workers.
    pub fn connect(addr: &str) -> anyhow::Result<Self> {
        Self::connect_with_probe(addr, HEALTH_PROBE_INTERVAL)
    }

    /// [`Self::connect`] with an explicit health-probe interval (the
    /// chaos harness and tests shorten it to observe flaps quickly).
    pub fn connect_with_probe(addr: &str, probe_interval: Duration) -> anyhow::Result<Self> {
        let (conn, peer) = dial(addr)?;
        let name: &'static str = Box::leak(format!("remote@{addr}").into_boxed_str());
        let health = WorkerHealth::new();
        let probe_stop = Arc::new(AtomicBool::new(false));
        let snapshot = CapSnapshot {
            standard: peer.standard,
            depthwise: peer.depthwise,
            pointwise: peer.pointwise,
        };
        let probe = spawn_probe(
            addr.to_string(),
            snapshot,
            Arc::clone(&health),
            Arc::clone(&probe_stop),
            probe_interval,
        );
        Ok(RemoteBackend {
            addr: addr.to_string(),
            name,
            peer,
            conn: Some(conn),
            next_id: 1,
            health,
            probe_stop,
            probe: Some(probe),
        })
    }

    /// The shared liveness flag (what [`ConvBackend::health`] exposes
    /// to the pool); public for harnesses that poll recovery.
    pub fn health_flag(&self) -> Arc<WorkerHealth> {
        Arc::clone(&self.health)
    }

    /// The peer address this backend fronts.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// I32-capable workers the peer advertised in its `hello`.
    pub fn peer_workers(&self) -> u64 {
        self.peer.workers
    }

    /// One request/reply exchange. The outer `Err` is a transport or
    /// protocol failure (stream desynced or dead — caller must drop the
    /// connection); the inner `Err(String)` is a *clean* job error the
    /// peer answered on a healthy, still-aligned stream (the connection
    /// stays up).
    fn round_trip(
        &mut self,
        id: u64,
        job: &JobPayload,
    ) -> anyhow::Result<Result<BackendRun, String>> {
        let conn = self.conn.as_mut().expect("connection ensured by run()");
        writeln!(conn.writer, "{}", request_json(id, job).to_json())?;
        let mut buf = Vec::new();
        let resp = loop {
            buf.clear();
            match read_line_capped(&mut conn.reader, &mut buf, MAX_LINE_BYTES)? {
                LineRead::Eof => anyhow::bail!("peer closed the connection mid-request"),
                LineRead::Line => {}
            }
            let line = String::from_utf8_lossy(&buf);
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let j = Json::parse(trimmed)
                .map_err(|e| anyhow::anyhow!("unparseable reply: {e}"))?;
            if j.get(&["hello"]).is_some() {
                continue; // stray greeting; keep draining
            }
            match j.get(&["id"]).and_then(Json::as_f64).map(|n| n as u64) {
                Some(rid) if rid == id => break j,
                // A stale reply to an older request this backend already
                // failed: drain it so the stream realigns.
                Some(_) => continue,
                None => anyhow::bail!("reply frame without an id"),
            }
        };
        if resp.get(&["ok"]).and_then(Json::as_bool) != Some(true) {
            let msg = resp
                .get(&["error"])
                .and_then(Json::as_str)
                .unwrap_or("unspecified peer error");
            return Ok(Err(msg.to_string()));
        }
        let shape: Vec<usize> = resp
            .get(&["shape"])
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("reply missing shape (peer ignored full_output)"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape element")))
            .collect::<Result<_, _>>()?;
        let data: Vec<i32> = resp
            .get(&["output"])
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("reply missing output (peer ignored full_output)"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|n| n as i32)
                    .ok_or_else(|| anyhow::anyhow!("bad output element"))
            })
            .collect::<Result<_, _>>()?;
        let want = expected_shape(job);
        anyhow::ensure!(
            shape == want,
            "peer output shape {shape:?} != expected {want:?}"
        );
        anyhow::ensure!(
            data.len() == shape.iter().product::<usize>(),
            "peer output length {} != shape {shape:?}",
            data.len()
        );
        let compute = resp
            .get(&["compute_cycles"])
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
        let total = resp
            .get(&["total_cycles"])
            .and_then(Json::as_f64)
            .unwrap_or(compute as f64) as u64;
        Ok(Ok(BackendRun {
            output: Tensor::from_vec(&shape, data),
            cycles: CycleStats {
                compute,
                total,
                ..Default::default()
            },
        }))
    }
}

impl ConvBackend for RemoteBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn capability(&self) -> Capability {
        Capability {
            standard3x3: self.peer.standard,
            depthwise: self.peer.depthwise,
            pointwise_as_3x3: self.peer.pointwise,
            accum: AccumMode::I32,
            // The v2 wire rejects standard/pointwise specs violating
            // §4.1 regardless of the peer's pool; the mask must mirror
            // that, or jobs a local host worker could serve get routed
            // here only to come back as peer errors.
            paper_specs_only: true,
            spec_allowlist: None,
        }
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Remote {
            class: self.peer.class,
        }
    }

    fn health(&self) -> Option<Arc<WorkerHealth>> {
        Some(Arc::clone(&self.health))
    }

    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
        job.validate()?;
        if self.conn.is_none() {
            // Reconnect after an earlier failure; the fresh handshake
            // re-verifies the peer still speaks v2. The pool snapshotted
            // this worker's capability at spawn, so a peer that comes
            // back *narrower* can't be served honestly any more — fail
            // loudly (every job errors with this message) instead of
            // letting jobs silently bounce off the peer's own mask.
            let (conn, fresh) = match dial(&self.addr) {
                Ok(ok) => ok,
                Err(e) => {
                    self.health.set_healthy(false);
                    return Err(e);
                }
            };
            if !((!self.peer.standard || fresh.standard)
                && (!self.peer.depthwise || fresh.depthwise)
                && (!self.peer.pointwise || fresh.pointwise))
            {
                self.health.set_healthy(false);
                anyhow::bail!(
                    "remote {}: peer restarted with a narrower capability than \
                     this pool's routing snapshot; rebuild the pool",
                    self.addr
                );
            }
            self.peer = fresh;
            self.conn = Some(conn);
        }
        let id = self.next_id;
        self.next_id += 1;
        match self.round_trip(id, job) {
            Ok(Ok(run)) => {
                self.health.set_healthy(true);
                Ok(run)
            }
            // A clean job-error frame arrived on an aligned stream: the
            // job fails but the connection is healthy — no redial churn,
            // and no health flap either.
            Ok(Err(job_err)) => Err(anyhow::anyhow!(
                "remote {}: peer answered with a job error: {job_err}",
                self.addr
            )),
            Err(e) => {
                // Transport/protocol failure: fail this in-flight job
                // and drop the connection; the next job redials instead
                // of reusing a wedged or desynced stream. Mark the peer
                // unhealthy right away so the dispatcher routes around
                // it without waiting for the next probe tick.
                self.conn = None;
                self.health.set_healthy(false);
                Err(anyhow::anyhow!("remote {}: {e}", self.addr))
            }
        }
    }
}

impl Drop for RemoteBackend {
    fn drop(&mut self) {
        self.probe_stop.store(true, Ordering::Relaxed);
        if let Some(probe) = self.probe.take() {
            let _ = probe.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::Batch;
    use crate::coordinator::config::CoordinatorConfig;
    use crate::coordinator::dispatch::CorePool;
    use crate::coordinator::request::{ConvJob, Submission};
    use crate::coordinator::tcp::TcpServer;
    use crate::hw::IpCoreConfig;
    use crate::model::LayerSpec;
    use std::io::BufRead;
    use std::net::TcpListener;
    use std::sync::mpsc::channel;

    /// A valid v2 greeting for hand-rolled fake peers.
    fn hello_line() -> &'static str {
        r#"{"hello":{"proto":2,"freq_hz":112000000,"cores":1,"workers":[{"backend":"sim-ipcore-i32","standard":true,"depthwise":true,"pointwise":true,"accum":"i32","model":"sim-cycles","quote":6272}]}}"#
    }

    #[test]
    fn connect_rejects_malformed_hello() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(s, "this is not a hello").unwrap();
        });
        let err = RemoteBackend::connect(&addr).unwrap_err();
        assert!(err.to_string().contains("hello"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn connect_rejects_wrong_protocol_revision() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(
                s,
                r#"{{"hello":{{"proto":1,"workers":[{{"backend":"x","standard":true,"accum":"i32"}}]}}}}"#
            )
            .unwrap();
        });
        let err = RemoteBackend::connect(&addr).unwrap_err();
        assert!(err.to_string().contains("protocol"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn connect_rejects_peer_without_i32_workers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(
                s,
                r#"{{"hello":{{"proto":2,"workers":[{{"backend":"sim-ipcore-wrap8","standard":true,"depthwise":false,"pointwise":true,"accum":"wrap8","quote":6272}}]}}}}"#
            )
            .unwrap();
        });
        let err = RemoteBackend::connect(&addr).unwrap_err();
        assert!(err.to_string().contains("i32"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn mid_stream_disconnect_fails_the_job_then_reconnects() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            // Connection 1: greet, swallow one request, drop mid-stream.
            {
                let (mut s, _) = listener.accept().unwrap();
                writeln!(s, "{}", hello_line()).unwrap();
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
            }
            // Connection 2 (the reconnect): greet and answer properly.
            let (mut s, _) = listener.accept().unwrap();
            writeln!(s, "{}", hello_line()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let req = Json::parse(line.trim()).unwrap();
            let id = req.get(&["id"]).unwrap().as_f64().unwrap();
            // All-zero 1x3x3 -> k=4 job: the answer is four zero words.
            let reply = Json::obj(vec![
                ("id", Json::num(id)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("shape", Json::arr_u64([4u64, 1, 1])),
                ("output", Json::arr_i64([0i64, 0, 0, 0])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
        });
        let mut be = RemoteBackend::connect(&addr).unwrap();
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
        };
        // Job 1 fails (dropped peer), job 2 succeeds over the redial.
        let err = be.run(&payload).unwrap_err();
        assert!(err.to_string().contains("remote"), "{err}");
        let run = be.run(&payload).unwrap();
        assert_eq!(run.output.shape(), &[4, 1, 1]);
        assert_eq!(run.output.data(), &[0, 0, 0, 0]);
        t.join().unwrap();
    }

    #[test]
    fn clean_peer_job_error_keeps_the_connection() {
        // The fake peer accepts exactly ONE connection: it errors job 1
        // cleanly, then serves job 2 on the same stream. If the client
        // wrongly redialled after the clean error, job 2 would have no
        // server to connect to and this test would fail.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            drop(listener); // no second accept possible
            writeln!(s, "{}", hello_line()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let id1 = Json::parse(line.trim()).unwrap().get(&["id"]).unwrap().as_f64().unwrap();
            let err = Json::obj(vec![
                ("id", Json::num(id1)),
                ("ok", Json::Bool(false)),
                ("error", Json::str("boom")),
            ]);
            writeln!(s, "{}", err.to_json()).unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            let id2 = Json::parse(line.trim()).unwrap().get(&["id"]).unwrap().as_f64().unwrap();
            let reply = Json::obj(vec![
                ("id", Json::num(id2)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("shape", Json::arr_u64([4u64, 1, 1])),
                ("output", Json::arr_i64([0i64, 0, 0, 0])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
        });
        let mut be = RemoteBackend::connect(&addr).unwrap();
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
        };
        let err = be.run(&payload).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
        let run = be.run(&payload).expect("same connection serves the next job");
        assert_eq!(run.output.data(), &[0, 0, 0, 0]);
        t.join().unwrap();
    }

    #[test]
    fn capability_and_cost_reflect_the_peer_hello() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_golden_workers(1),
        )
        .unwrap();
        let be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        let cap = be.capability();
        assert!(cap.standard3x3 && cap.depthwise && cap.pointwise_as_3x3);
        assert_eq!(cap.accum, AccumMode::I32);
        assert!(cap.paper_specs_only, "the wire applies the §4.1 gate");
        assert_eq!(be.peer_workers(), 2);
        // Pricing collapses to the fastest advertised tier (the sim
        // core), not the golden worker beside it.
        assert_eq!(
            be.cost_model(),
            CostModel::Remote {
                class: RemotePeerClass::SimCycles
            }
        );
        assert!(be.name().starts_with("remote@"));
        drop(be);
        server.stop();
    }

    #[test]
    fn host_only_peer_prices_as_host_class() {
        // A peer fronting only naive golden workers must advertise —
        // and be priced as — host loops, keeping local silicon
        // preferred in a mixed front pool.
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig {
                n_cores: 0,
                ..CoordinatorConfig::default().with_golden_workers(2)
            },
        )
        .unwrap();
        let be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        assert_eq!(
            be.cost_model(),
            CostModel::Remote {
                class: RemotePeerClass::HostMacs
            }
        );
        drop(be);
        server.stop();
    }

    #[test]
    fn dead_peer_yields_error_results_from_the_pool_not_hangs() {
        // The ISSUE's failure contract at pool level: a RemoteBackend
        // whose peer died answers dispatched jobs with error results.
        let server =
            TcpServer::start("127.0.0.1:0", CoordinatorConfig::default()).unwrap();
        let be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        server.stop();
        let backends: Vec<Box<dyn ConvBackend>> = vec![Box::new(be)];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        let job = ConvJob::synthetic(1, QUICKSTART, 1);
        pool.dispatch(Batch {
            spec: job.spec,
            weights_id: job.weights_id,
            kind: job.kind,
            accum: job.accum,
            jobs: vec![Submission {
                job,
                reply: tx,
                enqueued: std::time::Instant::now(),
            }],
        });
        let res = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("an error result, not a hang");
        assert!(res.error.is_some(), "{res:?}");
        pool.shutdown();
    }
}
