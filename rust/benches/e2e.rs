//! Bench: end-to-end serving — full CNN inference through the layer
//! scheduler, and mixed-trace throughput through the coordinator's core
//! pool at 1 / 4 / 20 cores (the §5.2 scaling story, measured through
//! the real dispatch path rather than multiplied out).

use repro::bench_util::{black_box, Bencher};
use repro::coordinator::{CnnScheduler, CoordinatorConfig, Server};
use repro::hw::IpCoreConfig;
use repro::model::network::EdgeCnn;
use repro::model::trace::{generate, TraceConfig};
use repro::paper::FREQ_Z2_HZ;

fn main() {
    println!("=== bench: e2e (edge CNN + coordinator) ===");
    let b = Bencher::default();

    // --- single inference through the scheduler.
    {
        let net = EdgeCnn::new(42);
        let first = net.specs()[0];
        let img = EdgeCnn::sample_input(1, &first);
        let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
        let run = sched.infer(&img).unwrap();
        println!(
            "sim latency/inference: {} cycles = {:.3} ms @112MHz (chaining; {} with DMA round-trips)",
            run.total_cycles,
            run.total_cycles as f64 / FREQ_Z2_HZ as f64 * 1e3,
            run.total_cycles_dma_roundtrip
        );
        b.run("edge_cnn inference (hw-sim, host time)", || {
            black_box(sched.infer(&img).unwrap())
        });
    }

    // --- coordinator trace throughput at increasing core counts.
    let trace = generate(&TraceConfig {
        n: 32,
        mean_gap_us: 0,
        s52_fraction: 0.0,
        depthwise_fraction: 0.0,
        seed: 7,
    });
    for cores in [1usize, 4, 20] {
        let mut server = Server::new(CoordinatorConfig::default().with_cores(cores));
        let report = server.run_trace(&trace);
        println!(
            "coordinator {:>2} cores: sim_gops={:.4} host_rps={:.1} p50={}us p99={}us wdma_skip={:.0}%",
            cores,
            report.sim_gops_psum,
            report.host_rps,
            report.p50_us,
            report.p99_us,
            report.weight_dma_skip_rate * 100.0
        );
        server.shutdown();
    }

    // --- heterogeneous pool: sim cores + golden fallback, mixed kinds.
    {
        let mixed = generate(&TraceConfig {
            n: 32,
            mean_gap_us: 0,
            s52_fraction: 0.0,
            depthwise_fraction: 0.25,
            seed: 8,
        });
        let mut server = Server::new(
            CoordinatorConfig::default().with_cores(4).with_golden_workers(2),
        );
        let report = server.run_trace(&mixed);
        println!(
            "heterogeneous 4 sim + 2 golden: host_rps={:.1} p99={}us mix={:?}",
            report.host_rps, report.p99_us, report.backend_mix
        );
        server.shutdown();
    }

    // --- host cost of one dispatch round trip (scheduling overhead).
    {
        let mut server = Server::new(CoordinatorConfig::default());
        let single = generate(&TraceConfig {
            n: 1,
            s52_fraction: 0.0,
            ..Default::default()
        });
        b.run("coordinator 1-request round trip", || {
            black_box(server.run_trace(&single))
        });
        server.shutdown();
    }
}
