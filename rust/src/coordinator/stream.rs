//! Whole-network streaming inference across the pool.
//!
//! [`super::scheduler::CnnScheduler`] chains a CNN's layers on *one*
//! backend, the way the paper's §4.1 chains output BRAMs into the next
//! layer's input. [`StreamScheduler`] generalises that chaining to the
//! whole heterogeneous pool: a client submits `(model_id, input_image)`
//! and the scheduler walks the registry manifest's layer chain across
//! whatever workers exist — depthwise layers only ever reach
//! depthwise-capable workers (the dispatch capability mask), pointwise
//! layers land on whichever worker quotes the cheapest load — applying
//! each inter-layer boundary transform (requantise / ReLU-by-clamp /
//! maxpool / re-pad, [`crate::registry::LayerParams::boundary`]) on the
//! front between hops. Weights ride the jobs by `weights_hash`, so a
//! wire-v4 peer that served layer k of image 0 serves layer k of every
//! later image from its content-addressed store without the blob ever
//! crossing the wire again.
//!
//! Images are **pipelined**: up to `window` images are in flight at
//! once, so layer k+1 of image i overlaps layer k of image i+1 on other
//! workers — the §4.1 chained dataflow stretched across machines.
//! `window == 1` degenerates to the serial baseline (one image fully
//! drains before the next is admitted); [`StreamOutcome::overlap_events`]
//! counts the layer completions that actually overlapped another
//! in-flight image, which is how the CI smoke proves the pipelining is
//! real and not just configured.
//!
//! Every image's final logits are checked against the manifest's own
//! CPU reference ([`crate::registry::ModelManifest::forward_golden`])
//! — streaming is an *execution* strategy, never a numerics change.

use super::batcher::Batch;
use super::dispatch::CorePool;
use super::request::{ConvResult, Submission};
use crate::model::Tensor;
use crate::registry::{ModelManifest, ModelRegistry};
use crate::telemetry::Stage;
use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

/// Job ids encode `(image, layer)` so one shared reply channel can
/// demultiplex the whole stream: `id = image * ID_STRIDE + layer`.
/// No model here comes near 1024 layers.
const ID_STRIDE: u64 = 1024;

/// How many times one layer hop may be resubmitted after an error
/// result (every resubmit re-enters capability-masked dispatch, which
/// itself retries across siblings). With [`RETRY_BACKOFF`] this gives a
/// killed-and-revived peer ~15 s to come back — the same patience as
/// the chaos harness's health-recovery deadline.
const MAX_LAYER_ATTEMPTS: u32 = 150;
const RETRY_BACKOFF: Duration = Duration::from_millis(100);

/// One image's journey through the stream.
#[derive(Clone, Debug)]
pub struct ImageOutcome {
    pub image: usize,
    /// Registry model index this image was submitted against.
    pub model: usize,
    /// Final-layer logits as served by the pool (empty on failure).
    pub logits: Vec<i32>,
    /// The manifest's CPU reference for the same input.
    pub golden: Vec<i32>,
    /// `logits == golden`, bit-exact.
    pub matches: bool,
    /// Set when the image could not be completed (every capable worker
    /// stayed down past the retry budget). Never silently dropped.
    pub error: Option<String>,
    /// Wall latency from admission to final logits.
    pub latency: Duration,
}

/// What one streaming run produced, beyond the pool-level metrics.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    pub images: Vec<ImageOutcome>,
    /// Layer completions that happened while at least one *other* image
    /// was in flight — the direct evidence of cross-image pipelining.
    /// Zero when `window == 1`.
    pub overlap_events: u64,
    /// Successfully answered layer jobs (resubmits count once, on the
    /// attempt that succeeded).
    pub n_layer_jobs: usize,
    /// Error results that triggered a layer resubmission.
    pub n_resubmits: usize,
    /// Mean per-layer-index serving latency in µs (index = layer depth;
    /// models of different depths fold into the same vector).
    pub mean_layer_latency_us: Vec<u64>,
    /// Answered layer jobs per backend name.
    pub backend_mix: Vec<(&'static str, usize)>,
    pub wall: Duration,
}

impl StreamOutcome {
    /// Every image completed and matched its golden reference.
    pub fn all_match(&self) -> bool {
        self.images.iter().all(|o| o.matches)
    }

    pub fn images_per_sec(&self) -> f64 {
        self.images.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Internal per-image progress: which layer is in flight and what its
/// input was (retained so an error result can be resubmitted).
struct ImageState {
    model: usize,
    layer: usize,
    input: Tensor<u8>,
    attempts: u32,
    admitted: Instant,
    /// Tracing tile cursor: where the previous hop's accounting ended.
    /// Layer/Boundary spans tile `[mark, now]` contiguously, so the
    /// union of an image's child spans covers its Request root with no
    /// scheduler-loop gaps.
    mark: Instant,
}

/// The streaming front: walks every image's layer chain across the
/// pool, `window` images in flight at once. Borrowed (not owned) pool
/// and registry: the same pool serves trace fronts before and after a
/// stream.
pub struct StreamScheduler<'a> {
    pool: &'a CorePool,
    registry: &'a ModelRegistry,
    window: usize,
}

impl<'a> StreamScheduler<'a> {
    pub fn new(pool: &'a CorePool, registry: &'a ModelRegistry, window: usize) -> Self {
        StreamScheduler {
            pool,
            registry,
            window: window.max(1),
        }
    }

    /// Stream `n_images` images (image i drives model `i % n_models`,
    /// input generated from `seed` via the registry's deterministic
    /// scheme) and return every outcome.
    pub fn run(&self, n_images: usize, seed: u64) -> StreamOutcome {
        self.run_with(n_images, seed, &mut |_| {})
    }

    /// Like [`Self::run`], with `on_image(i)` fired just before image
    /// `i` is admitted — the chaos harness's hook for killing and
    /// reviving peers mid-stream.
    pub fn run_with(
        &self,
        n_images: usize,
        seed: u64,
        on_image: &mut dyn FnMut(usize),
    ) -> StreamOutcome {
        let (tx, rx) = channel::<ConvResult>();
        let start = Instant::now();
        // Per-image trace ids are minted here (image i → i+1, nonzero)
        // when the pool carries a span sink; the front owns each
        // image's Request root — per-layer hops propagate the id with
        // `trace.layer` set so no downstream stage mints a second root.
        let sink = self.pool.span_sink();
        let mut inflight: BTreeMap<usize, ImageState> = BTreeMap::new();
        let mut outcomes: Vec<Option<ImageOutcome>> = (0..n_images).map(|_| None).collect();
        let mut finished = 0usize;
        let mut next_image = 0usize;
        let mut overlap_events = 0u64;
        let mut n_layer_jobs = 0usize;
        let mut n_resubmits = 0usize;
        let mut layer_lat: Vec<(u64, u64)> = Vec::new(); // (sum_us, count)
        let mut mix: BTreeMap<&'static str, usize> = BTreeMap::new();

        while finished < n_images {
            // Admit images up to the window; this is what creates the
            // cross-image overlap (window == 1 serialises the stream).
            while inflight.len() < self.window && next_image < n_images {
                let i = next_image;
                next_image += 1;
                on_image(i);
                let model = i % self.registry.n_models();
                let manifest = &self.registry.models()[model];
                let input = manifest.sample_image(seed ^ ((i as u64) << 1));
                let admitted = Instant::now();
                let state = ImageState {
                    model,
                    layer: 0,
                    input,
                    attempts: 0,
                    admitted,
                    mark: admitted,
                };
                let tid = if sink.is_some() { i as u64 + 1 } else { 0 };
                self.submit(&tx, manifest, i, &state, tid);
                inflight.insert(i, state);
            }

            let r = match rx.recv() {
                Ok(r) => r,
                Err(_) => unreachable!("scheduler holds a sender while images are in flight"),
            };
            let image = (r.id / ID_STRIDE) as usize;
            let layer = (r.id % ID_STRIDE) as usize;
            // Stale results (a duplicate from a failed-over worker, or a
            // hop that was already resubmitted) are dropped, not applied.
            let model = match inflight.get(&image) {
                Some(s) if s.layer == layer => s.model,
                _ => continue,
            };
            let manifest = &self.registry.models()[model];

            if let Some(err) = r.error {
                // Every capable worker failed this hop (dispatch already
                // tried siblings). Back off and resubmit: a killed peer
                // may be revived, and the pool's health probe will fold
                // it back in. Bounded — a permanently dead fleet surfaces
                // as a per-image error outcome, never a hang.
                n_resubmits += 1;
                let attempts = {
                    let s = inflight.get_mut(&image).expect("state present");
                    s.attempts += 1;
                    s.attempts
                };
                if attempts > MAX_LAYER_ATTEMPTS {
                    let state = inflight.remove(&image).expect("state present");
                    if let Some(sink) = &sink {
                        // Even a failed image leaves a complete tree:
                        // the last Layer tile absorbs the retry tail.
                        let tid = image as u64 + 1;
                        let now = Instant::now();
                        sink.span(tid, Stage::Layer(layer as u16), 0, state.mark, now);
                        sink.span(tid, Stage::Request, 0, state.admitted, now);
                    }
                    outcomes[image] = Some(ImageOutcome {
                        image,
                        model,
                        logits: Vec::new(),
                        golden: manifest
                            .forward_golden(
                                &manifest.sample_image(seed ^ ((image as u64) << 1)),
                            )
                            .into_data(),
                        matches: false,
                        error: Some(err),
                        latency: state.admitted.elapsed(),
                    });
                    finished += 1;
                    continue;
                }
                std::thread::sleep(RETRY_BACKOFF);
                let tid = if sink.is_some() { image as u64 + 1 } else { 0 };
                self.submit(&tx, manifest, image, &inflight[&image], tid);
                continue;
            }

            // A good layer result. Count the overlap first: did it
            // complete while another image was also mid-network?
            if inflight.len() > 1 {
                overlap_events += 1;
            }
            n_layer_jobs += 1;
            *mix.entry(r.backend).or_default() += 1;
            if layer_lat.len() <= layer {
                layer_lat.resize(layer + 1, (0, 0));
            }
            layer_lat[layer].0 += r.latency.as_micros() as u64;
            layer_lat[layer].1 += 1;

            // Stage accounting: the Layer tile runs from the previous
            // hop's end (`mark`) to here — queue + compute + everything
            // the scheduler loop spent on this hop — then the boundary
            // transform gets its own tile, so the per-image span tree
            // stays gap-free.
            let hop_end = Instant::now();
            let tid = if sink.is_some() { image as u64 + 1 } else { 0 };
            let mark = inflight[&image].mark;
            self.pool
                .metrics
                .stages
                .layer(layer)
                .record_us(hop_end.saturating_duration_since(mark).as_micros() as u64);
            if let Some(sink) = &sink {
                sink.span(tid, Stage::Layer(layer as u16), 0, mark, hop_end);
            }
            let next = manifest.layers[layer].boundary(&r.output);
            let boundary_end = Instant::now();
            self.pool.metrics.stages.boundary.record_us(
                boundary_end
                    .saturating_duration_since(hop_end)
                    .as_micros() as u64,
            );
            if let Some(sink) = &sink {
                sink.span(tid, Stage::Boundary, 0, hop_end, boundary_end);
            }

            match next {
                Some(next_input) => {
                    // Inter-layer boundary applied on the front; hand the
                    // next layer to whichever worker dispatch picks.
                    {
                        let s = inflight.get_mut(&image).expect("state present");
                        s.layer = layer + 1;
                        s.input = next_input;
                        s.attempts = 0;
                        s.mark = boundary_end;
                    }
                    self.submit(&tx, manifest, image, &inflight[&image], tid);
                }
                None => {
                    // Final layer: raw logits. Check against the
                    // manifest's own CPU reference.
                    let state = inflight.remove(&image).expect("state present");
                    // Root span closes at the boundary check, before
                    // the golden CPU reference run — serving latency,
                    // not verification cost.
                    if let Some(sink) = &sink {
                        sink.span(tid, Stage::Request, 0, state.admitted, boundary_end);
                    }
                    let golden = manifest
                        .forward_golden(&manifest.sample_image(seed ^ ((image as u64) << 1)))
                        .into_data();
                    let logits = r.output.into_data();
                    outcomes[image] = Some(ImageOutcome {
                        image,
                        model,
                        matches: logits == golden,
                        logits,
                        golden,
                        error: None,
                        latency: state.admitted.elapsed(),
                    });
                    finished += 1;
                }
            }
        }
        drop(tx);

        StreamOutcome {
            images: outcomes
                .into_iter()
                .map(|o| o.expect("every admitted image reaches an outcome"))
                .collect(),
            overlap_events,
            n_layer_jobs,
            n_resubmits,
            mean_layer_latency_us: layer_lat
                .iter()
                .map(|&(sum, n)| if n == 0 { 0 } else { sum / n })
                .collect(),
            backend_mix: mix.into_iter().collect(),
            wall: start.elapsed(),
        }
    }

    /// Submit one image's current layer as a single-job batch. Streaming
    /// hops skip the cross-request batcher: each hop's input exists only
    /// after the previous hop, so there is nothing same-weight to
    /// coalesce with at submission time — weight reuse comes from the
    /// wire-v4 store (repeat images) instead of batch adjacency.
    fn submit(
        &self,
        tx: &std::sync::mpsc::Sender<ConvResult>,
        manifest: &ModelManifest,
        image: usize,
        state: &ImageState,
        trace_id: u64,
    ) {
        let id = image as u64 * ID_STRIDE + state.layer as u64;
        let mut job = manifest
            .layer_job(state.layer, id, state.input.clone())
            .expect("manifest layer chain is internally consistent");
        if trace_id != 0 {
            // Propagate the image's trace id; `layer` marks this as a
            // mid-stream hop so the dispatcher (and any remote peer's
            // dispatcher) never mints a second Request root for it.
            job.trace.id = trace_id;
            job.trace.layer = Some(state.layer.min(u16::MAX as usize) as u16);
        }
        let batch = Batch {
            spec: job.spec,
            weights_id: job.weights_id,
            kind: job.kind,
            accum: job.accum,
            jobs: vec![Submission {
                job,
                reply: tx.clone(),
                enqueued: Instant::now(),
            }],
        };
        self.pool.dispatch(batch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::IpCoreConfig;

    fn local_pool(cores: usize) -> CorePool {
        CorePool::new(cores, IpCoreConfig::default())
    }

    #[test]
    fn stream_matches_golden_per_image_and_overlaps() {
        let pool = local_pool(2);
        let reg = ModelRegistry::builtin(2, 11);
        let sched = StreamScheduler::new(&pool, &reg, 4);
        let out = sched.run(6, 5);
        assert_eq!(out.images.len(), 6);
        for o in &out.images {
            assert!(o.error.is_none(), "image {} errored: {:?}", o.image, o.error);
            assert!(o.matches, "image {} diverged from golden", o.image);
            assert!(!o.logits.is_empty());
            assert_eq!(o.model, o.image % 2, "round-robin model assignment");
        }
        // Window 4 over 6 images: the very first completion already has
        // other images in flight.
        assert!(out.overlap_events > 0, "no pipelining observed");
        // Every model here is at least 3 layers deep.
        assert!(out.mean_layer_latency_us.len() >= 3);
        let total_layers: usize = (0..out.images.len())
            .map(|i| reg.n_layers(i % 2))
            .sum();
        assert_eq!(out.n_layer_jobs, total_layers);
        assert_eq!(out.n_resubmits, 0);
        assert!(out.images_per_sec() > 0.0);
        pool.shutdown();
    }

    #[test]
    fn window_one_serialises_images() {
        let pool = local_pool(2);
        let reg = ModelRegistry::builtin(1, 7);
        let sched = StreamScheduler::new(&pool, &reg, 1);
        let out = sched.run(3, 9);
        assert!(out.all_match(), "{:?}", out.images);
        assert_eq!(
            out.overlap_events, 0,
            "window=1 must never overlap images"
        );
        pool.shutdown();
    }

    #[test]
    fn stream_is_deterministic_across_runs_and_window_sizes() {
        // The window changes *scheduling*, never numerics: logits for
        // the same (registry, seed) are identical at any window.
        let reg = ModelRegistry::builtin(2, 13);
        let pool_a = local_pool(1);
        let a = StreamScheduler::new(&pool_a, &reg, 1).run(4, 21);
        let pool_b = local_pool(3);
        let b = StreamScheduler::new(&pool_b, &reg, 4).run(4, 21);
        assert!(a.all_match() && b.all_match());
        for (x, y) in a.images.iter().zip(&b.images) {
            assert_eq!(x.logits, y.logits);
            assert_eq!(x.golden, y.golden);
        }
        pool_a.shutdown();
        pool_b.shutdown();
    }

    #[test]
    fn traced_stream_tiles_layer_spans_into_complete_image_trees() {
        use crate::backend::{ConvBackend, SimBackend};
        use crate::telemetry::{validate_coverage, SpanSink, Stage};
        use std::sync::Arc;

        let sink = Arc::new(SpanSink::new());
        let backends: Vec<Box<dyn ConvBackend>> = (0..2)
            .map(|_| Box::new(SimBackend::new(IpCoreConfig::default())) as Box<dyn ConvBackend>)
            .collect();
        let pool = CorePool::with_backends_traced(
            backends,
            IpCoreConfig::default(),
            Some(Arc::clone(&sink)),
        );
        let reg = ModelRegistry::builtin(2, 11);
        let out = StreamScheduler::new(&pool, &reg, 3).run(4, 5);
        assert!(out.all_match(), "{:?}", out.images);

        // One Request root per image, and every image's Layer/Boundary
        // tiles cover its root — gap-free by construction.
        let spans = sink.snapshot();
        let check = validate_coverage(&spans).expect("complete per-image trees");
        assert_eq!(check.roots, 4, "one root per streamed image");
        assert!(spans.iter().any(|s| s.stage == Stage::Layer(0)));
        assert!(spans.iter().any(|s| s.stage == Stage::Boundary));

        // Per-layer stage histograms saw every hop, boundary every one.
        let total_layers: usize = (0..4).map(|i| reg.n_layers(i % 2)).sum();
        let layer_count: u64 = (0..crate::coordinator::metrics::N_LAYER_STAGES)
            .map(|l| pool.metrics.stages.layer(l).count())
            .sum();
        assert_eq!(layer_count as usize, total_layers);
        assert_eq!(pool.metrics.stages.boundary.count() as usize, total_layers);
        pool.shutdown();
    }

    #[test]
    fn stream_hook_fires_once_per_image_in_admission_order() {
        let pool = local_pool(2);
        let reg = ModelRegistry::builtin(1, 3);
        let sched = StreamScheduler::new(&pool, &reg, 2);
        let mut seen = Vec::new();
        let out = sched.run_with(4, 1, &mut |i| seen.push(i));
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert!(out.all_match());
        pool.shutdown();
    }
}
