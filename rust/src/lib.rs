//! Reproduction of *"An FPGA-based Solution for Convolution Operation
//! Acceleration"* (Pham-Dinh et al., 2022) as a layered
//! rust + JAX + Pallas system.
//!
//! The layers, bottom to top:
//!
//! * [`hw`] — the paper's Verilog IP core (4 computing cores × 4
//!   PCOREs, weight stationary, BRAM-quartered channels, 2-stage
//!   load/compute pipeline) as a **cycle-accurate simulator** (no FPGA
//!   is available; DESIGN.md documents the substitution).
//! * [`model`] — tensors, layer specs, the golden CPU reference every
//!   compute path is tested against, quantisation, the edge CNN and
//!   workload-trace generation.
//! * [`runtime`] — the same convolution compiled AOT from JAX + Pallas
//!   into HLO-text artifacts, executed through PJRT (behind the `xla`
//!   feature; an API-identical stub keeps tier-1 builds toolchain-free).
//! * [`backend`] — **the execution seam**: one [`backend::ConvBackend`]
//!   trait in front of every way a conv layer can run — the simulated
//!   IP core (standard, pointwise-as-3×3 and depthwise through one
//!   entry point), the naive golden anchor, the threaded im2col+GEMM
//!   host worker ([`backend::Im2colBackend`], the serious CPU
//!   fallback), the XLA path, and whole remote machines over TCP
//!   ([`backend::RemoteBackend`], wire protocol v2/v3/v4) — each
//!   reporting a capability descriptor and a dispatch cost model. The parity
//!   contract (bit-identical i32 outputs across backends, every kind,
//!   both accumulator modes) is enforced by the unified harness in
//!   `rust/tests/backend_parity.rs` — for the remote backend,
//!   end-to-end over a real socket.
//! * [`coordinator`] — the serving layer: kind- and accum-tagged
//!   requests, weight-stationary batching, a heterogeneous worker pool
//!   (`Box<dyn ConvBackend>` per worker — e.g. the paper's 20 simulated
//!   cores plus `golden_fallback_workers`/`im2col_workers` host
//!   workers plus `remote_peers` fleet members) with capability-masked,
//!   cost-weighted least-loaded dispatch, a CNN layer scheduler that
//!   chains output BRAMs into the next layer's input (§4.1), and a
//!   JSON-over-TCP front end speaking the negotiated wire protocol
//!   (`repro fleet N` composes both sides into a multi-machine demo).
//! * [`store`] + [`registry`] — multi-tenant weight residency: a
//!   content-addressed LRU weight store (BRAM-budgeted, one per
//!   `TcpServer`) and a model registry (`model_id → ordered layers +
//!   weight hashes`) so wire v4 ships each distinct weight blob to a
//!   peer at most once and serves every later job from residency.
//! * [`telemetry`] — observability: per-request distributed tracing
//!   (admission/queue/dispatch/wire/compute/boundary spans into a
//!   bounded lock-free [`telemetry::SpanSink`], exported as Chrome
//!   trace-event JSON) and a live Prometheus scrape endpoint
//!   ([`telemetry::scrape`]) over the stage-keyed latency histograms
//!   and per-worker gauges — all without touching numerics.
//!
//! Experiment index (DESIGN.md §4): Fig. 6 → [`hw::waveform`] +
//! `examples/waveform_repro.rs`; Table 1 → [`hw::resource`]; §5.2
//! throughput → [`hw::ip_core`] + `examples/multicore_scaling.rs`
//! (which also scales a mixed sim+golden pool).

pub mod backend;
pub mod bench_util;
pub mod coordinator;
pub mod hw;
pub mod model;
pub mod registry;
pub mod runtime;
pub mod store;
pub mod telemetry;
pub mod util;

/// Paper constants that recur across modules.
pub mod paper {
    /// Fixed kernel window of the IP core (§2.1, §4.2).
    pub const KH: usize = 3;
    /// Fixed kernel window of the IP core (§2.1, §4.2).
    pub const KW: usize = 3;
    /// Computing cores per IP core (§4.2 "Multi-Channel Architecture").
    pub const N_CORES: usize = 4;
    /// PCOREs per computing core (§4.2 "Multi-Kernel Computing Core").
    pub const N_PCORES: usize = 4;
    /// Clock cycles for one (window × 4 kernels) PSUM group (§5.2).
    pub const CYCLES_PER_PSUM_GROUP: u64 = 8;
    /// Pynq Z2 (xc7z020clg400-1) max frequency from Table 1.
    pub const FREQ_Z2_HZ: u64 = 112_000_000;
    /// IP cores deployable on a fully-utilised Pynq Z2 (§5.1: <5% per core).
    pub const MAX_CORES_Z2: usize = 20;
    /// §5.2 headline: single IP core throughput, GOPS (PSUMs/s accounting).
    pub const GOPS_SINGLE: f64 = 0.224;
    /// §5.2 headline: 20-core throughput, GOPS.
    pub const GOPS_20: f64 = 4.48;
}
