//! Two-stage pipeline model (§4.2 "Pipeline"): stage 1 moves data from
//! the BRAMs into the loaders, stage 2 computes and accumulates PSUMs.
//!
//! For a sequence of steps with load times `l_i` and compute times
//! `c_i`, the classic two-stage timing is
//!
//! ```text
//! serial    = Σ (l_i + c_i)
//! pipelined = l_0 + Σ_{i=1..n-1} max(l_i, c_{i-1}) + c_{n-1}
//! ```
//!
//! The IP core's steady state has `c_i = 8 ≥ l_i` (slides cost 2, fresh
//! windows 5), so pipelining hides essentially all load time — the
//! "effectively cutting down the wasted cycles" claim. The closed forms
//! below let the fast path skip per-step iteration for large layers.

/// Exact pipelined total over explicit per-step (load, compute) pairs.
pub fn two_stage_pipelined(steps: &[(u64, u64)]) -> u64 {
    match steps {
        [] => 0,
        [(l, c)] => l + c,
        _ => {
            let mut total = steps[0].0;
            for i in 1..steps.len() {
                total += steps[i].0.max(steps[i - 1].1);
            }
            total + steps[steps.len() - 1].1
        }
    }
}

/// Exact serial total (pipeline disabled — the ablation baseline).
pub fn two_stage_serial(steps: &[(u64, u64)]) -> u64 {
    steps.iter().map(|(l, c)| l + c).sum()
}

/// Closed-form pipelined total when every compute step costs `compute`
/// and every load fits under it except the very first (`first_load`):
/// `first_load + n*compute`.
pub fn pipelined_closed_form(n_steps: u64, first_load: u64, compute: u64) -> u64 {
    if n_steps == 0 {
        0
    } else {
        first_load + n_steps * compute
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_single() {
        assert_eq!(two_stage_pipelined(&[]), 0);
        assert_eq!(two_stage_serial(&[]), 0);
        assert_eq!(two_stage_pipelined(&[(5, 8)]), 13);
        assert_eq!(two_stage_serial(&[(5, 8)]), 13);
    }

    #[test]
    fn compute_bound_steady_state() {
        // loads (<=8) fully hidden: 5 + 4*8 + 8? No: l0 + Σ max + c_last
        let steps = [(5, 8), (2, 8), (2, 8), (2, 8)];
        assert_eq!(two_stage_pipelined(&steps), 5 + 8 + 8 + 8 + 8);
        assert_eq!(two_stage_serial(&steps), 13 + 10 + 10 + 10);
    }

    #[test]
    fn load_bound_steps_stall() {
        let steps = [(10, 2), (10, 2)];
        // 10 + max(10,2) + 2 = 22
        assert_eq!(two_stage_pipelined(&steps), 22);
        assert_eq!(two_stage_serial(&steps), 24);
    }

    #[test]
    fn closed_form_matches_exact() {
        let n = 100u64;
        let steps: Vec<(u64, u64)> = (0..n)
            .map(|i| (if i == 0 { 5 } else { 2 }, 8))
            .collect();
        assert_eq!(
            two_stage_pipelined(&steps),
            pipelined_closed_form(n, 5, 8)
        );
    }

    #[test]
    fn pipelined_never_slower_than_serial() {
        let cases = [
            vec![(1u64, 1u64)],
            vec![(5, 8), (2, 8), (9, 3)],
            vec![(0, 0), (7, 7), (3, 1), (1, 3)],
        ];
        for steps in &cases {
            assert!(two_stage_pipelined(steps) <= two_stage_serial(steps));
        }
    }
}
