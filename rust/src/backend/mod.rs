//! The execution-backend seam: every way this system can compute a
//! convolution layer sits behind one [`ConvBackend`] trait.
//!
//! The paper ships a single fixed-function IP core; a deployment mixes
//! compute substrates — replicated accelerator cores, host-CPU
//! fallback, a compiled XLA path — and routes each layer job to a
//! capable, least-loaded unit (the pattern the FPGA-CNN survey
//! literature calls heterogeneous per-layer scheduling). This module
//! is that seam:
//!
//! * [`ConvBackend`] — executes one conv-layer job ([`JobPayload`]) and
//!   reports its output plus a simulated/modelled cost ([`BackendRun`]);
//! * [`Capability`] — what the backend can run: standard 3×3,
//!   depthwise, pointwise-as-3×3, and which accumulator mode it
//!   produces;
//! * [`CostModel`] — a cheap, `Copy` cost estimator the dispatcher uses
//!   for capability-masked, cost-weighted least-loaded routing without
//!   reaching into worker threads;
//! * [`sim::SimBackend`] — the cycle-accurate [`crate::hw::IpCore`]
//!   (standard, pointwise-as-3×3, and depthwise through the same entry
//!   point);
//! * [`golden::GoldenBackend`] — the naive CPU reference, the honest
//!   host-fallback worker;
//! * [`xla::XlaBackend`] — the AOT Pallas/HLO artifacts under PJRT
//!   (available when the `xla` feature is linked and artifacts exist).
//!
//! The parity contract: for identical integer inputs every backend
//! produces bit-identical i32 outputs (`rust/tests/backend_parity.rs`).

pub mod golden;
pub mod sim;
pub mod xla;

pub use golden::GoldenBackend;
pub use sim::SimBackend;
pub use xla::XlaBackend;

use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::{LayerSpec, Tensor};
use crate::paper::{CYCLES_PER_PSUM_GROUP, N_CORES, N_PCORES};

/// What kind of convolution a job asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum JobKind {
    /// The paper's standard 3×3 conv: `(C,H,W) ⊛ (K,C,3,3) + (K,)`.
    Standard,
    /// Per-channel 3×3: `(C,H,W) ⊛ (C,3,3) + (C,)`, `spec.k == spec.c`.
    /// ReLU fuses into the core's depthwise path (`spec.relu`).
    Depthwise,
    /// A 1×1 conv pre-lowered to the core's 3×3 dataflow: the image
    /// arrives zero-padded by one pixel and the weights centre-tapped
    /// (see [`crate::hw::depthwise::pointwise_as_3x3`]). Numerically a
    /// standard job; tracked separately so backends can decline the
    /// 11%-MAC-utilisation mapping.
    PointwiseAs3x3,
}

/// PSUMs a job contributes in the paper's accounting — kind-aware:
/// depthwise accumulates one PSUM per (window, channel), not per
/// (window, kernel, channel).
pub fn job_psums(spec: &LayerSpec, kind: JobKind) -> u64 {
    match kind {
        JobKind::Depthwise => (spec.conv_oh() * spec.conv_ow() * spec.c) as u64,
        JobKind::Standard | JobKind::PointwiseAs3x3 => spec.psums(),
    }
}

/// What a backend can execute, and in which accumulator mode.
#[derive(Clone, Debug)]
pub struct Capability {
    pub standard3x3: bool,
    pub depthwise: bool,
    pub pointwise_as_3x3: bool,
    /// Accumulator semantics of the outputs this backend produces.
    /// Mixed pools serving production traffic should be I32-homogeneous;
    /// the dispatcher masks by job kind and leaves accumulator policy to
    /// pool construction.
    pub accum: AccumMode,
    /// `Some(specs)` when the backend can only serve a fixed spec set
    /// (the XLA path serves exactly its compiled artifacts); `None`
    /// means any valid spec of a supported kind. The dispatcher must
    /// honour this — a mask/run mismatch panics the worker thread.
    pub spec_allowlist: Option<Vec<LayerSpec>>,
}

impl Capability {
    pub fn supports(&self, kind: JobKind) -> bool {
        match kind {
            JobKind::Standard => self.standard3x3,
            JobKind::Depthwise => self.depthwise,
            JobKind::PointwiseAs3x3 => self.pointwise_as_3x3,
        }
    }

    /// Full routing predicate: kind mask plus the spec allowlist.
    pub fn allows(&self, spec: &LayerSpec, kind: JobKind) -> bool {
        self.supports(kind)
            && match &self.spec_allowlist {
                None => true,
                Some(list) => list.contains(spec),
            }
    }
}

/// Dispatcher-side cost estimator. `Copy`, so the pool can weigh queue
/// load on the submit thread while the backend itself lives inside a
/// worker thread. Units are "equivalent busy cycles" of the owning
/// backend — only relative magnitudes within one pool matter for
/// least-loaded balancing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostModel {
    /// The IP core's closed-form schedule (§5.2): standard jobs cost
    /// `windows × ceil(C/4) × K/4 × 8` cycles, depthwise jobs
    /// `windows × ceil(C/4) × 8` (one active PCORE).
    SimCycles,
    /// Naive host loops: ~one unit per MAC (9 per PSUM).
    HostMacs,
    /// Vectorised host runtime: `psums / throughput_factor` units.
    Vectorized { throughput_factor: u64 },
}

impl CostModel {
    pub fn cost(&self, spec: &LayerSpec, kind: JobKind) -> u64 {
        let windows = (spec.conv_oh() * spec.conv_ow()) as u64;
        let c_rounds = spec.c.div_ceil(N_CORES) as u64;
        match (*self, kind) {
            (CostModel::SimCycles, JobKind::Depthwise) => {
                c_rounds * windows * CYCLES_PER_PSUM_GROUP
            }
            (CostModel::SimCycles, _) => {
                let kernel_groups = (spec.k as u64 / N_PCORES as u64).max(1);
                windows * c_rounds * kernel_groups * CYCLES_PER_PSUM_GROUP
            }
            (CostModel::HostMacs, kind) => job_psums(spec, kind) * 9,
            (CostModel::Vectorized { throughput_factor }, kind) => {
                job_psums(spec, kind) / throughput_factor.max(1) + 1
            }
        }
    }
}

/// One conv-layer job in backend-agnostic, borrowed form.
///
/// Shapes by kind — `Standard`/`PointwiseAs3x3`: image `(C,H,W)`,
/// weights `(K,C,3,3)`, bias `(K,)`; `Depthwise`: weights `(C,3,3)`,
/// bias `(C,)`, `spec.k == spec.c`.
#[derive(Debug)]
pub struct JobPayload<'a> {
    pub kind: JobKind,
    pub spec: &'a LayerSpec,
    pub img: &'a Tensor<u8>,
    pub weights: &'a Tensor<u8>,
    pub bias: &'a [i32],
    /// The dispatcher already has this weight set resident on the
    /// executing unit (weight-stationary batching): backends that model
    /// a weight DMA may discount it.
    pub weights_resident: bool,
}

/// What one backend execution produced.
#[derive(Clone, Debug)]
pub struct BackendRun {
    /// Widened i32 output (backends in narrower accumulator modes widen
    /// on readout, exactly like `LayerOutput::into_i32`).
    pub output: Tensor<i32>,
    /// Simulated cycles for hardware backends; modelled equivalent
    /// cycles (the backend's [`CostModel`]) for host paths. Drives
    /// metrics and load accounting uniformly.
    pub cycles: CycleStats,
}

/// A unit that executes conv-layer jobs. `Send` is a supertrait so
/// boxed backends can move into pool worker threads.
pub trait ConvBackend: Send {
    /// Stable identifier (distinct per configuration where it matters,
    /// e.g. `sim-ipcore-wrap8` vs `sim-ipcore-i32`).
    fn name(&self) -> &'static str;

    /// What this backend can run.
    fn capability(&self) -> Capability;

    /// Dispatcher-side cost estimator for this backend.
    fn cost_model(&self) -> CostModel;

    /// Estimated cost of one job (provided: delegates to the model).
    fn cost(&self, spec: &LayerSpec, kind: JobKind) -> u64 {
        self.cost_model().cost(spec, kind)
    }

    /// Execute one job. Standard/pointwise jobs return the raw
    /// accumulator output (activation + requant belong to the serving
    /// layer); depthwise fuses ReLU when `spec.relu` is set, matching
    /// the core's depthwise entry point.
    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{QUICKSTART, S52};

    #[test]
    fn sim_cost_matches_s52_cycle_count() {
        // The cost model must agree with the simulator's §5.2 headline.
        let c = CostModel::SimCycles.cost(&S52, JobKind::Standard);
        assert_eq!(c, 1_577_088);
    }

    #[test]
    fn depthwise_psums_drop_the_kernel_axis() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        assert_eq!(job_psums(&spec, JobKind::Standard), 64 * 8 * 8);
        assert_eq!(job_psums(&spec, JobKind::Depthwise), 64 * 8);
    }

    #[test]
    fn capability_masks_by_kind() {
        let cap = Capability {
            standard3x3: true,
            depthwise: false,
            pointwise_as_3x3: true,
            accum: AccumMode::I32,
            spec_allowlist: None,
        };
        assert!(cap.supports(JobKind::Standard));
        assert!(cap.supports(JobKind::PointwiseAs3x3));
        assert!(!cap.supports(JobKind::Depthwise));
        assert!(cap.allows(&QUICKSTART, JobKind::Standard));
    }

    #[test]
    fn spec_allowlist_restricts_routing() {
        let cap = Capability {
            standard3x3: true,
            depthwise: false,
            pointwise_as_3x3: false,
            accum: AccumMode::I32,
            spec_allowlist: Some(vec![QUICKSTART]),
        };
        assert!(cap.allows(&QUICKSTART, JobKind::Standard));
        assert!(!cap.allows(&S52, JobKind::Standard));
        // Kind mask still applies on top of the allowlist.
        assert!(!cap.allows(&QUICKSTART, JobKind::Depthwise));
    }

    #[test]
    fn host_cost_exceeds_sim_cost_per_job() {
        // Golden fallback must look more expensive than an IP core so
        // least-loaded dispatch prefers accelerators until they queue.
        let sim = CostModel::SimCycles.cost(&QUICKSTART, JobKind::Standard);
        let host = CostModel::HostMacs.cost(&QUICKSTART, JobKind::Standard);
        assert!(host > sim, "host {host} vs sim {sim}");
    }

    #[test]
    fn vectorized_cost_is_never_zero() {
        let tiny = LayerSpec::new(1, 3, 3, 4);
        let c = CostModel::Vectorized { throughput_factor: 1_000_000 }.cost(&tiny, JobKind::Standard);
        assert!(c >= 1);
    }
}
