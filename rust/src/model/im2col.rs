//! im2col + GEMM software baseline — and the serious host kernel.
//!
//! The paper compares against no software baseline; a reproduction
//! should. This is the standard CPU realisation of the same 3×3 valid
//! convolution — lower the image to a patch matrix, multiply by the
//! flattened weights — implemented independently of both the golden
//! loops and the hardware model, so it doubles as a third numeric
//! witness. The benches report its host throughput next to the
//! simulated core and the XLA path (EXPERIMENTS.md E2E/ABL).
//!
//! Two GEMMs live here:
//!
//! * [`gemm_i32`] — the naive scalar loop, kept as the fair
//!   single-thread baseline the benches compare against;
//! * [`gemm_i32_blocked`] — the production host kernel behind
//!   [`crate::backend::Im2colBackend`]: the A matrix is split into
//!   contiguous row panels (one scoped thread each, no shared mutable
//!   state — each thread owns a disjoint slice of the output) and the
//!   inner dimension is walked in cache-sized blocks so a B panel stays
//!   resident while a row panel streams through it.
//!
//! Bit-exactness contract: for every output element both GEMMs
//! accumulate the same products in the same (ascending-`l`) order, so
//! their i32 results are identical — not merely close — and the
//! backend parity suite (`rust/tests/backend_parity.rs`) holds the
//! threaded path to the same bit-identical standard as the simulator.

use super::tensor::Tensor;
use crate::paper::{KH, KW};

/// Inner-dimension block of [`gemm_i32_blocked`]: 64 i32 `A` values plus
/// a 64-row stripe of `B` sit comfortably in L1 next to the output row.
pub const GEMM_KK_BLOCK: usize = 64;

/// Lower `(C,H,W)` u8 image to the `(OH*OW, C*9)` i32 patch matrix.
pub fn im2col(img: &Tensor<u8>) -> (Tensor<i32>, usize, usize) {
    let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let (oh, ow) = (h - KH + 1, w - KW + 1);
    let cols = c * KH * KW;
    let mut out = Tensor::<i32>::zeros(&[oh * ow, cols]);
    let data = out.data_mut();
    for y in 0..oh {
        for x in 0..ow {
            let row = y * ow + x;
            let base = row * cols;
            for ci in 0..c {
                for dy in 0..KH {
                    for dx in 0..KW {
                        data[base + (ci * KH + dy) * KW + dx] =
                            img.at3(ci, y + dy, x + dx) as i32;
                    }
                }
            }
        }
    }
    (out, oh, ow)
}

/// Flatten `(K,C,3,3)` weights to the `(C*9, K)` GEMM operand.
pub fn weights_matrix(w: &Tensor<u8>) -> Tensor<i32> {
    let (k, c) = (w.shape()[0], w.shape()[1]);
    let rows = c * KH * KW;
    let mut out = Tensor::<i32>::zeros(&[rows, k]);
    let data = out.data_mut();
    for ki in 0..k {
        for ci in 0..c {
            for dy in 0..KH {
                for dx in 0..KW {
                    data[((ci * KH + dy) * KW + dx) * k + ki] = w.at4(ki, ci, dy, dx) as i32;
                }
            }
        }
    }
    out
}

/// Plain i32 GEMM: `(m,n) = (m,kk) @ (kk,n)`, row-major, with a simple
/// kk-blocked inner loop (enough to be a fair scalar-CPU baseline).
pub fn gemm_i32(a: &Tensor<i32>, b: &Tensor<i32>) -> Tensor<i32> {
    let (m, kk) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(kk, kb, "inner dims");
    let mut out = Tensor::<i32>::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * kk..(i + 1) * kk];
        let orow = &mut od[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let brow = &bd[l * n..(l + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Cache-blocked, row-panel-parallel GEMM: `(m,n) = (m,kk) @ (kk,n)`,
/// row-major, bit-identical to [`gemm_i32`] (see the module docs for
/// the ordering argument). `threads` scoped worker threads each own a
/// contiguous panel of output rows; `threads <= 1` (or a single-panel
/// problem) runs inline with no spawn.
pub fn gemm_i32_blocked(a: &Tensor<i32>, b: &Tensor<i32>, threads: usize) -> Tensor<i32> {
    let (m, kk) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(kk, kb, "inner dims");
    let mut out = Tensor::<i32>::zeros(&[m, n]);
    if m == 0 || n == 0 {
        return out;
    }
    let threads = threads.clamp(1, m);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    if threads == 1 {
        gemm_panel(ad, bd, od, m, kk, n);
        return out;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, panel) in od.chunks_mut(rows_per * n).enumerate() {
            let rows = panel.len() / n;
            let a_panel = &ad[t * rows_per * kk..(t * rows_per + rows) * kk];
            scope.spawn(move || gemm_panel(a_panel, bd, panel, rows, kk, n));
        }
    });
    out
}

/// One row panel: `out[rows,n] += a[rows,kk] @ b[kk,n]`, walking the
/// inner dimension in [`GEMM_KK_BLOCK`]-sized stripes. Per output
/// element the products arrive in ascending-`l` order — the exact
/// order [`gemm_i32`] uses — so the two are bit-identical.
fn gemm_panel(a: &[i32], b: &[i32], out: &mut [i32], rows: usize, kk: usize, n: usize) {
    let mut l0 = 0;
    while l0 < kk {
        let l1 = (l0 + GEMM_KK_BLOCK).min(kk);
        for i in 0..rows {
            let arow = &a[i * kk + l0..i * kk + l1];
            let orow = &mut out[i * n..(i + 1) * n];
            for (dl, &av) in arow.iter().enumerate() {
                if av == 0 {
                    continue;
                }
                let brow = &b[(l0 + dl) * n..(l0 + dl + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        l0 = l1;
    }
}

/// `(OH*OW, K)` GEMM product → the hardware's `(K, OH, OW)` layout,
/// adding bias and (optionally) fusing ReLU on the way through.
fn scatter_bias_relu(
    prod: &Tensor<i32>,
    k: usize,
    oh: usize,
    ow: usize,
    bias: &[i32],
    relu: bool,
) -> Tensor<i32> {
    let mut out = Tensor::<i32>::zeros(&[k, oh, ow]);
    for ki in 0..k {
        for y in 0..oh {
            for x in 0..ow {
                let mut v = prod.data()[(y * ow + x) * k + ki] + bias[ki];
                if relu && v < 0 {
                    v = 0;
                }
                out.set3(ki, y, x, v);
            }
        }
    }
    out
}

/// The full baseline: conv via im2col + GEMM (+ bias, optional ReLU),
/// output in the hardware's `(K, OH, OW)` layout.
pub fn conv3x3_im2col(
    img: &Tensor<u8>,
    w: &Tensor<u8>,
    bias: &[i32],
    relu: bool,
) -> Tensor<i32> {
    let k = w.shape()[0];
    let (patches, oh, ow) = im2col(img);
    let wm = weights_matrix(w);
    let prod = gemm_i32(&patches, &wm); // (OH*OW, K)
    scatter_bias_relu(&prod, k, oh, ow, bias, relu)
}

/// [`conv3x3_im2col`] over the blocked parallel GEMM — the host kernel
/// [`crate::backend::Im2colBackend`] runs. Bit-identical to the naive
/// baseline (and therefore to the golden anchor) for any thread count.
pub fn conv3x3_im2col_threaded(
    img: &Tensor<u8>,
    w: &Tensor<u8>,
    bias: &[i32],
    relu: bool,
    threads: usize,
) -> Tensor<i32> {
    let k = w.shape()[0];
    let (patches, oh, ow) = im2col(img);
    let wm = weights_matrix(w);
    let prod = gemm_i32_blocked(&patches, &wm, threads);
    scatter_bias_relu(&prod, k, oh, ow, bias, relu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::golden;
    use crate::util::prng::Prng;

    fn case(c: usize, h: usize, w: usize, k: usize, seed: u64) -> (Tensor<u8>, Tensor<u8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        (
            Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256)),
            Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256)),
            (0..k).map(|_| rng.range_i64(-50, 50) as i32).collect(),
        )
    }

    #[test]
    fn im2col_patch_layout() {
        let img = Tensor::from_vec(&[1, 3, 4], (0..12u8).collect());
        let (p, oh, ow) = im2col(&img);
        assert_eq!((oh, ow), (1, 2));
        // First patch: cols 0..3 of rows 0..3.
        assert_eq!(&p.data()[..9], &[0, 1, 2, 4, 5, 6, 8, 9, 10]);
        // Second patch slides one column.
        assert_eq!(&p.data()[9..18], &[1, 2, 3, 5, 6, 7, 9, 10, 11]);
    }

    #[test]
    fn gemm_small_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1, 2, 3, 4]);
        let b = Tensor::from_vec(&[2, 2], vec![5, 6, 7, 8]);
        assert_eq!(gemm_i32(&a, &b).data(), &[19, 22, 43, 50]);
    }

    #[test]
    fn matches_golden_over_shapes() {
        for (c, h, w, k, seed) in [
            (1, 3, 3, 4, 1u64),
            (4, 8, 8, 4, 2),
            (8, 10, 7, 8, 3),
            (3, 6, 9, 12, 4),
        ] {
            let (img, wts, bias) = case(c, h, w, k, seed);
            for relu in [false, true] {
                let a = conv3x3_im2col(&img, &wts, &bias, relu);
                let b = golden::conv3x3_i32(&img, &wts, &bias, relu);
                assert_eq!(a.data(), b.data(), "c{c} h{h} w{w} k{k} relu={relu}");
            }
        }
    }

    #[test]
    fn blocked_gemm_matches_naive_on_conv_shapes() {
        for (c, h, w, k, seed) in [(4usize, 8, 8, 4, 11u64), (8, 10, 7, 8, 12), (3, 17, 9, 12, 13)] {
            let (img, wts, _) = case(c, h, w, k, seed);
            let (patches, _, _) = im2col(&img);
            let wm = weights_matrix(&wts);
            let want = gemm_i32(&patches, &wm);
            for threads in [1usize, 2, 4, 7] {
                let got = gemm_i32_blocked(&patches, &wm, threads);
                assert_eq!(got.shape(), want.shape());
                assert_eq!(got.data(), want.data(), "c{c} h{h} w{w} k{k} threads={threads}");
            }
        }
    }

    #[test]
    fn blocked_gemm_handles_degenerate_and_offblock_shapes() {
        // Inner dim straddling the block boundary, row counts below and
        // above the thread count, single row/column.
        for (m, kk, n) in [(1usize, 1usize, 1usize), (3, 65, 2), (130, 64, 5), (5, 63, 1)] {
            let mut rng = Prng::new((m * 1000 + kk * 10 + n) as u64);
            let a = Tensor::from_vec(&[m, kk], (0..m * kk).map(|_| rng.range_i64(-99, 99) as i32).collect());
            let b = Tensor::from_vec(&[kk, n], (0..kk * n).map(|_| rng.range_i64(-99, 99) as i32).collect());
            let want = gemm_i32(&a, &b);
            for threads in [1usize, 4, 16] {
                assert_eq!(gemm_i32_blocked(&a, &b, threads).data(), want.data(), "m{m} kk{kk} n{n}");
            }
        }
    }

    #[test]
    fn threaded_conv_matches_baseline_and_golden() {
        for (c, h, w, k, seed) in [(1usize, 3, 3, 4, 21u64), (8, 12, 12, 8, 22), (5, 9, 14, 16, 23)] {
            let (img, wts, bias) = case(c, h, w, k, seed);
            for relu in [false, true] {
                let want = golden::conv3x3_i32(&img, &wts, &bias, relu);
                for threads in [1usize, 3, 4] {
                    let got = conv3x3_im2col_threaded(&img, &wts, &bias, relu, threads);
                    assert_eq!(got.data(), want.data(), "c{c} k{k} relu={relu} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn matches_hw_simulator() {
        let (img, wts, bias) = case(8, 12, 12, 8, 5);
        let spec = crate::model::LayerSpec::new(8, 12, 12, 8);
        let run = crate::hw::IpCore::new(crate::hw::IpCoreConfig::default())
            .run_layer(&spec, &img, &wts, &bias, None)
            .unwrap();
        let baseline = conv3x3_im2col(&img, &wts, &bias, false);
        assert_eq!(run.output.as_i32().data(), baseline.data());
    }
}
