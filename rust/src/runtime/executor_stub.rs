//! Featureless stand-in for [`super::executor`]: the same `XlaRuntime`
//! surface, but construction always fails. Built when the `xla` feature
//! is off so the tier-1 build needs no PJRT toolchain while every
//! caller keeps compiling; callers already treat a failed constructor
//! as "XLA unavailable — skip".

use super::artifacts::ArtifactRegistry;
use crate::model::{LayerSpec, Tensor};

/// Stub runtime; cannot be constructed (both constructors return
/// `Err`), so the `&mut self` methods are unreachable by construction.
pub struct XlaRuntime {
    pub registry: ArtifactRegistry,
    /// Executions performed (metrics).
    pub executions: u64,
}

fn unavailable() -> anyhow::Error {
    anyhow::anyhow!(
        "XlaRuntime is unavailable: this binary was built without the `xla` \
         feature (rebuild with `--features xla` and a PJRT-linked xla crate)"
    )
}

impl XlaRuntime {
    pub fn new(_registry: ArtifactRegistry) -> anyhow::Result<Self> {
        Err(unavailable())
    }

    pub fn with_default_registry() -> anyhow::Result<Self> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Execute a variant with f32 tensor inputs.
    pub fn execute(&mut self, _name: &str, _inputs: &[Tensor<f32>]) -> anyhow::Result<Tensor<f32>> {
        Err(unavailable())
    }

    /// Run one conv layer (u8 image/weights, i32 bias → f32 carriers).
    pub fn run_layer(
        &mut self,
        _spec: &LayerSpec,
        _img: &Tensor<u8>,
        _weights: &Tensor<u8>,
        _bias: &[i32],
    ) -> anyhow::Result<Tensor<f32>> {
        Err(unavailable())
    }

    /// Run the fused edge CNN artifact: image + (w, b) per layer.
    pub fn run_edge_cnn(
        &mut self,
        _img: &Tensor<u8>,
        _params: &[(Tensor<u8>, Vec<i32>)],
    ) -> anyhow::Result<Vec<f32>> {
        Err(unavailable())
    }

    pub fn compiled_count(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_the_missing_feature() {
        let err = XlaRuntime::with_default_registry().unwrap_err();
        assert!(err.to_string().contains("xla"));
    }
}
