//! Artifact registry: the rust view of `artifacts/manifest.json`.

use crate::model::LayerSpec;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled variant (a conv layer or the fused CNN).
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub kind: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Input shapes in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shape.
    pub output: Vec<usize>,
    /// For conv layers: the layer spec.
    pub spec: Option<LayerSpec>,
}

/// The registry: manifest + artifact directory.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
}

fn shape_list(j: &Json) -> Vec<Vec<usize>> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .map(|s| {
                    s.as_arr()
                        .map(|d| d.iter().filter_map(|v| v.as_usize()).collect())
                        .unwrap_or_default()
                })
                .collect()
        })
        .unwrap_or_default()
}

impl ArtifactRegistry {
    /// Load from a directory containing `manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            )
        })?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        anyhow::ensure!(
            json.get(&["format"]).and_then(Json::as_str) == Some("hlo-text"),
            "manifest format must be hlo-text (see aot.py)"
        );
        let mut variants = BTreeMap::new();
        let vmap = json
            .get(&["variants"])
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest has no variants"))?;
        for (name, v) in vmap {
            let kind = v
                .get(&["kind"])
                .and_then(Json::as_str)
                .unwrap_or("conv_layer")
                .to_string();
            let spec = if kind == "conv_layer" {
                Some(LayerSpec {
                    c: v.get(&["c"]).and_then(Json::as_usize).unwrap_or(0),
                    h: v.get(&["h"]).and_then(Json::as_usize).unwrap_or(0),
                    w: v.get(&["w"]).and_then(Json::as_usize).unwrap_or(0),
                    k: v.get(&["k"]).and_then(Json::as_usize).unwrap_or(0),
                    relu: v.get(&["relu"]).and_then(Json::as_bool).unwrap_or(false),
                    pool: v.get(&["pool"]).and_then(Json::as_bool).unwrap_or(false),
                })
            } else {
                None
            };
            variants.insert(
                name.clone(),
                Variant {
                    name: name.clone(),
                    kind,
                    file: v
                        .get(&["file"])
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("variant {name} missing file"))?
                        .to_string(),
                    inputs: v.get(&["inputs"]).map(shape_list).unwrap_or_default(),
                    output: v
                        .get(&["output"])
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default(),
                    spec,
                },
            );
        }
        Ok(ArtifactRegistry { dir, variants })
    }

    /// Default location: `$REPRO_ARTIFACTS` or `<repo>/artifacts`.
    pub fn load_default() -> anyhow::Result<Self> {
        if let Ok(dir) = std::env::var("REPRO_ARTIFACTS") {
            return Self::load(dir);
        }
        let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Self::load(repo)
    }

    /// Find the variant serving a given layer spec.
    pub fn for_spec(&self, spec: &LayerSpec) -> Option<&Variant> {
        self.variants.get(&spec.name())
    }

    /// Absolute path of a variant's HLO file.
    pub fn hlo_path(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.file)
    }

    /// All conv-layer specs the registry can serve.
    pub fn served_specs(&self) -> Vec<LayerSpec> {
        self.variants.values().filter_map(|v| v.spec).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QUICKSTART;

    fn registry() -> Option<ArtifactRegistry> {
        ArtifactRegistry::load_default().ok()
    }

    #[test]
    fn loads_manifest_and_serves_quickstart() {
        let Some(reg) = registry() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let v = reg.for_spec(&QUICKSTART).expect("quickstart variant");
        assert_eq!(v.kind, "conv_layer");
        assert_eq!(v.output, vec![8, 14, 14]);
        assert!(reg.hlo_path(v).exists());
        assert_eq!(v.inputs[0], vec![8, 16, 16]);
    }

    #[test]
    fn edge_cnn_variant_present() {
        let Some(reg) = registry() else {
            return;
        };
        let cnn = reg.variants.get("edge_cnn").expect("edge_cnn");
        assert_eq!(cnn.kind, "cnn");
        assert_eq!(cnn.inputs.len(), 1 + 10); // image + 5x(w,b)
    }

    #[test]
    fn served_specs_round_trip_names() {
        let Some(reg) = registry() else {
            return;
        };
        for spec in reg.served_specs() {
            assert!(reg.for_spec(&spec).is_some(), "{}", spec.name());
        }
    }
}
