//! Chaos leg of the parity harness: the failure-tolerant serving front
//! under a mid-trace peer kill.
//!
//! Three in-process wire-v4 peers join one remote-only front pool —
//! the first pinned to legacy wire v2, so the pool is mixed-protocol
//! and every invariant below holds across both framings at once. One
//! peer is severed mid-trace (its port stays bound — connections drop,
//! exactly a crashed process) and later revived. A second leg runs
//! multi-model registry traffic through a flapped v4 peer and pins the
//! weight-store contract: the redial wipes the front's known-hash
//! beliefs, so each blob is re-shipped at most once per connection
//! epoch, bit-identically. The invariants:
//!
//! * every admitted request completes **bit-identical** to
//!   `GoldenBackend` on the same tensors — failover hops may change
//!   which worker answers, never the numerics (the parity harness's
//!   contract, extended through dispatcher retries);
//! * a failing worker's jobs are re-enqueued on capable siblings
//!   (`retried` counts hops, `failed` stays zero);
//! * the killed peer's worker is marked unhealthy by the background
//!   probe and masked out of routing — degraded capacity, not
//!   correctness;
//! * after revival the probe flips it healthy again (`recovered_peers`)
//!   and the peer serves fresh traffic.
//!
//! A streaming leg repeats the kill/revive while whole-network images
//! are pipelined across the fleet: no image may be lost, every image's
//! logits stay bit-identical to the registry golden, and the revived
//! peer serves later streaming layers.

use repro::backend::{ConvBackend, GoldenBackend, JobKind};
use repro::coordinator::batcher::Batch;
use repro::coordinator::request::{ConvJob, ConvResult, Submission};
use repro::coordinator::server::build_pool;
use repro::coordinator::tcp::TcpServer;
use repro::coordinator::{CoordinatorConfig, Server};
use repro::model::trace::{generate, TraceConfig};
use repro::model::Tensor;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

const N_PEERS: usize = 3;
const N_REQUESTS: usize = 48;
const KILL_AT: usize = 16;
const REVIVE_AT: usize = 32;

fn start_fleet() -> (Vec<TcpServer>, CoordinatorConfig) {
    let mut peers = Vec::new();
    for i in 0..N_PEERS {
        // Peer 0 is pinned to legacy wire v2: the front must negotiate
        // JSON tensors with it while speaking binary v4 frames to its
        // siblings — a mixed-protocol pool under chaos.
        let mut pc = CoordinatorConfig::default().with_cores(2);
        if i == 0 {
            pc = pc.with_wire_v2_only();
        }
        peers.push(TcpServer::start("127.0.0.1:0", pc).expect("in-process wire peer"));
    }
    let addrs: Vec<String> = peers.iter().map(|p| p.addr.to_string()).collect();
    let config = CoordinatorConfig {
        n_cores: 0,
        ..CoordinatorConfig::default().with_remote_peers(addrs)
    };
    (peers, config)
}

/// Wrap one synthetic trace entry as a single-job batch plus the
/// golden-reference output for its exact tensors.
fn entry_to_case(
    i: usize,
    entry: &repro::model::trace::TraceEntry,
    golden: &mut GoldenBackend,
) -> (Batch, Receiver<ConvResult>, Tensor<i32>) {
    let job = match entry.kind {
        JobKind::Depthwise => ConvJob::synthetic_depthwise(i as u64, entry.spec, entry.seed),
        _ => ConvJob::synthetic(i as u64, entry.spec, entry.seed),
    };
    let want = golden
        .run(&job.payload(false))
        .expect("golden reference")
        .output;
    let (tx, rx) = channel();
    let batch = Batch {
        spec: job.spec,
        weights_id: job.weights_id,
        kind: job.kind,
        accum: job.accum,
        jobs: vec![Submission {
            job,
            reply: tx,
            enqueued: Instant::now(),
        }],
    };
    (batch, rx, want)
}

#[test]
fn killed_peer_mid_trace_fails_over_bit_identically_then_revives() {
    let (peers, config) = start_fleet();
    let pool = build_pool(&config).expect("front pool dials all three peers");
    let mut golden = GoldenBackend::new();
    let trace = generate(&TraceConfig {
        n: N_REQUESTS,
        mean_gap_us: 0,
        s52_fraction: 0.0, // keep the burst fast; shapes still mixed
        depthwise_fraction: 0.25,
        seed: 61,
    });

    // Submit the whole trace, severing the last peer just before entry
    // KILL_AT and reviving it before entry REVIVE_AT.
    let mut pending = Vec::new();
    for (i, entry) in trace.iter().enumerate() {
        if i == KILL_AT {
            peers[N_PEERS - 1].set_down(true);
        }
        if i == REVIVE_AT {
            peers[N_PEERS - 1].set_down(false);
        }
        let (batch, rx, want) = entry_to_case(i, entry, &mut golden);
        assert!(
            pool.try_dispatch(batch).is_ok(),
            "remote pool routes all kinds (entry {i})"
        );
        pending.push((i, rx, want));
    }

    // Every request is answered with the reference numerics — failover
    // may move jobs between peers but never changes a single bit.
    for (i, rx, want) in pending {
        let result = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("entry {i} never answered: {e}"));
        assert!(
            result.error.is_none(),
            "entry {i} answered with an error despite failover: {:?}",
            result.error
        );
        assert_eq!(
            result.output.data(),
            want.data(),
            "entry {i}: failover changed the numerics"
        );
    }

    let retried = pool.metrics.retried.load(Ordering::Relaxed);
    let failed = pool.metrics.failed.load(Ordering::Relaxed);
    let completed = pool.metrics.completed.load(Ordering::Relaxed);
    assert_eq!(completed, N_REQUESTS as u64, "every job completed");
    assert_eq!(failed, 0, "failover must leave no terminal failures");
    assert!(
        retried >= 1,
        "the killed peer was load-balanced traffic; at least one job must have hopped"
    );

    // The probe notices the revival: the worker flips back healthy and
    // the recovery edge is counted.
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let health = pool.worker_health();
        if *health.last().unwrap() && pool.recovered_peers() >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "probe never marked the revived peer healthy again: {health:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // And the revived peer serves fresh traffic: push bursts until its
    // own server answers some of them (bounded, not first-try — load
    // balancing decides which worker each job lands on).
    let before = peers[N_PEERS - 1].metrics().completed.load(Ordering::Relaxed);
    let mut served = false;
    'waves: for wave in 0..50u64 {
        let wave_trace = generate(&TraceConfig {
            n: 8,
            mean_gap_us: 0,
            s52_fraction: 0.0,
            depthwise_fraction: 0.0,
            seed: 7000 + wave,
        });
        let mut rxs = Vec::new();
        for (j, entry) in wave_trace.iter().enumerate() {
            let (batch, rx, want) = entry_to_case(j, entry, &mut golden);
            assert!(pool.try_dispatch(batch).is_ok(), "routable wave");
            rxs.push((rx, want));
        }
        for (rx, want) in rxs {
            let r = rx.recv_timeout(Duration::from_secs(60)).expect("wave answered");
            assert!(r.error.is_none(), "wave job errored post-revive: {:?}", r.error);
            assert_eq!(r.output.data(), want.data(), "wave numerics");
        }
        if peers[N_PEERS - 1].metrics().completed.load(Ordering::Relaxed) > before {
            served = true;
            break 'waves;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(served, "revived peer never served traffic again");

    pool.shutdown();
    for p in peers {
        p.stop();
    }
}

/// Wrap one registry submission as a single-job batch plus the golden
/// reference for its exact tensors (the registry analogue of
/// [`entry_to_case`]).
fn registry_case(
    registry: &repro::registry::ModelRegistry,
    i: u64,
    seed: u64,
    golden: &mut GoldenBackend,
) -> (Batch, Receiver<ConvResult>, Tensor<i32>) {
    let (m, l) = registry.pick(i, seed);
    let job = registry.job(m, l, i, seed ^ (i << 1)).expect("in-range pick");
    let want = golden
        .run(&job.payload(false))
        .expect("golden reference")
        .output;
    let (tx, rx) = channel();
    let batch = Batch {
        spec: job.spec,
        weights_id: job.weights_id,
        kind: job.kind,
        accum: job.accum,
        jobs: vec![Submission {
            job,
            reply: tx,
            enqueued: Instant::now(),
        }],
    };
    (batch, rx, want)
}

#[test]
fn flapped_peer_reships_each_weight_blob_at_most_once_per_epoch() {
    // Registry traffic over two v4 peers; the last peer is severed
    // mid-trace and revived. The flap drops the front's connection, the
    // redial wipes its known-hash beliefs, and the weight-store
    // contract must hold across the whole test:
    //   * every answer is bit-identical to golden (failover included);
    //   * the stable peer sees each distinct blob at most once, ever;
    //   * the flapped peer sees each blob at most once per connection
    //     epoch (two epochs here), and really does re-ship after the
    //     revive instead of trusting stale beliefs.
    use repro::registry::ModelRegistry;

    let mut peers = Vec::new();
    for _ in 0..2 {
        peers.push(
            TcpServer::start("127.0.0.1:0", CoordinatorConfig::default().with_cores(2))
                .expect("in-process wire-v4 peer"),
        );
    }
    let addrs: Vec<String> = peers.iter().map(|p| p.addr.to_string()).collect();
    let config = CoordinatorConfig {
        n_cores: 0,
        ..CoordinatorConfig::default().with_remote_peers(addrs)
    };
    let pool = build_pool(&config).expect("front pool dials both peers");
    let mut golden = GoldenBackend::new();
    let registry = ModelRegistry::builtin(2, 21);

    // One connection epoch's re-ship budget: the registry's distinct
    // weight blobs, by bytes.
    let mut blobs = std::collections::BTreeMap::new();
    for m in registry.models() {
        for l in &m.layers {
            blobs.insert(l.weights_hash, l.weights.data().len() as u64);
        }
    }
    let distinct_bytes: u64 = blobs.values().sum();
    assert!(distinct_bytes > 0);

    let mut pending = Vec::new();
    let mut w1_at_kill = 0u64;
    for i in 0..40usize {
        if i == KILL_AT {
            peers[1].set_down(true);
            // Frozen while down: the accept loop drops new connections.
            w1_at_kill = peers[1].metrics().wire_weight_bytes.load(Ordering::Relaxed);
        }
        if i == REVIVE_AT {
            peers[1].set_down(false);
        }
        let (batch, rx, want) = registry_case(&registry, i as u64, 21, &mut golden);
        assert!(
            pool.try_dispatch(batch).is_ok(),
            "registry jobs are routable (entry {i})"
        );
        pending.push((i, rx, want));
    }
    for (i, rx, want) in pending {
        let result = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("entry {i} never answered: {e}"));
        assert!(
            result.error.is_none(),
            "entry {i} answered with an error despite failover: {:?}",
            result.error
        );
        assert_eq!(
            result.output.data(),
            want.data(),
            "entry {i}: the flap changed the numerics"
        );
    }

    // Push post-revive registry waves until the flapped peer serves
    // again — its first job on the fresh connection must re-ship.
    let before = peers[1].metrics().completed.load(Ordering::Relaxed);
    let mut served = false;
    'waves: for wave in 0..50u64 {
        let mut rxs = Vec::new();
        for j in 0..8u64 {
            let (batch, rx, want) =
                registry_case(&registry, 1000 + wave * 8 + j, 21, &mut golden);
            assert!(pool.try_dispatch(batch).is_ok(), "routable wave");
            rxs.push((rx, want));
        }
        for (rx, want) in rxs {
            let r = rx.recv_timeout(Duration::from_secs(60)).expect("wave answered");
            assert!(r.error.is_none(), "wave job errored post-revive: {:?}", r.error);
            assert_eq!(r.output.data(), want.data(), "wave numerics");
        }
        if peers[1].metrics().completed.load(Ordering::Relaxed) > before {
            served = true;
            break 'waves;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(served, "revived peer never served traffic again");

    let w0 = peers[0].metrics().wire_weight_bytes.load(Ordering::Relaxed);
    let w1 = peers[1].metrics().wire_weight_bytes.load(Ordering::Relaxed);
    assert!(
        w0 <= distinct_bytes,
        "stable peer was re-shipped a blob it already holds: {w0} > {distinct_bytes}"
    );
    assert!(
        w1 <= 2 * distinct_bytes,
        "flapped peer re-shipped more than once per epoch: {w1} > {}",
        2 * distinct_bytes
    );
    assert!(
        w1 > w1_at_kill,
        "the post-revive connection must re-ship (stale hash beliefs survived the redial)"
    );

    pool.shutdown();
    for p in peers {
        p.stop();
    }
}

#[test]
fn mid_stream_peer_kill_loses_no_image_and_revived_peer_serves_again() {
    // Whole-network streaming under chaos: images hop layer-by-layer
    // across the mixed-protocol fleet while the last peer is severed
    // mid-stream and later revived. The contract:
    //   * no image is lost — every admitted image reaches final logits;
    //   * every image's logits stay bit-identical to the manifest's
    //     golden forward (failover hops and resubmitted layers may move
    //     work between peers, never change a bit);
    //   * after the revive, the peer serves streaming traffic again.
    use repro::registry::ModelRegistry;

    const N_IMAGES: usize = 12;
    const KILL_AT_IMAGE: usize = 4;
    const REVIVE_AT_IMAGE: usize = 8;

    let (peers, config) = start_fleet();
    let mut front = Server::try_new(config.with_stream_window(4)).expect("front pool");
    let registry = ModelRegistry::builtin(2, 33);
    let seed = 43u64;
    let (report, outcome) = front.run_stream_trace(&registry, N_IMAGES, seed, &mut |i| {
        if i == KILL_AT_IMAGE {
            peers[N_PEERS - 1].set_down(true);
        }
        if i == REVIVE_AT_IMAGE {
            peers[N_PEERS - 1].set_down(false);
        }
    });

    assert_eq!(report.n_images, N_IMAGES, "no image lost to the kill");
    assert_eq!(outcome.images.len(), N_IMAGES);
    for o in &outcome.images {
        assert!(
            o.error.is_none(),
            "image {} errored despite failover/resubmission: {:?}",
            o.image,
            o.error
        );
        // Independent reference: the manifest golden over the same
        // derived input, not the scheduler's own bookkeeping.
        let manifest = &registry.models()[o.model];
        let want = manifest
            .forward_golden(&manifest.sample_image(seed ^ ((o.image as u64) << 1)))
            .into_data();
        assert_eq!(
            o.logits, want,
            "image {}: chaos changed the numerics",
            o.image
        );
    }
    assert!(
        outcome.overlap_events > 0,
        "stream must pipeline across the kill window"
    );

    // The revived peer serves *later streaming layers*: push small
    // streams until its own server's completion counter moves (bounded;
    // the front's health probe needs a beat to re-dial).
    let before = peers[N_PEERS - 1].metrics().completed.load(Ordering::Relaxed);
    let mut served = false;
    for wave in 0..50u64 {
        let (r, out) = front.run_stream_trace(&registry, 3, 5000 + wave, &mut |_| {});
        assert_eq!(r.n_errors, 0, "post-revive stream errored: {r:?}");
        assert!(out.all_match(), "post-revive stream diverged: {:?}", out.images);
        if peers[N_PEERS - 1].metrics().completed.load(Ordering::Relaxed) > before {
            served = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(served, "revived peer never served streaming traffic again");

    front.shutdown();
    for p in peers {
        p.stop();
    }
}

#[test]
fn run_trace_with_chaos_hook_answers_every_request() {
    // The same scenario through the serving front the CLI drives:
    // `Server::run_trace_with` kills and revives the last peer via the
    // per-entry hook, and the report proves no request was lost.
    let (peers, config) = start_fleet();
    let mut front = Server::try_new(config).expect("front pool");
    let trace = generate(&TraceConfig {
        n: N_REQUESTS,
        mean_gap_us: 0,
        s52_fraction: 0.0,
        depthwise_fraction: 0.25,
        seed: 62,
    });
    let report = front.run_trace_with(&trace, &mut |i| {
        if i == KILL_AT {
            peers[N_PEERS - 1].set_down(true);
        }
        if i == REVIVE_AT {
            peers[N_PEERS - 1].set_down(false);
        }
    });
    assert_eq!(report.n_requests, N_REQUESTS);
    assert_eq!(report.n_errors, 0, "failover must absorb the kill: {report:?}");
    assert_eq!(report.n_shed, 0, "no admission budget configured");
    let served: usize = report.backend_mix.iter().map(|(_, n)| n).sum();
    assert_eq!(served, N_REQUESTS);
    assert!(
        report
            .backend_mix
            .iter()
            .all(|(name, _)| name.starts_with("remote@")),
        "{:?}",
        report.backend_mix
    );
    front.shutdown();
    for p in peers {
        p.stop();
    }
}
