//! End-to-end integration: full CNN inference through the scheduler
//! (simulated hardware, §4.1 layer chaining) and mixed traffic through
//! the coordinator's batcher + core pool.

use repro::coordinator::{CnnScheduler, CoordinatorConfig, Server};
use repro::hw::IpCoreConfig;
use repro::model::network::EdgeCnn;
use repro::model::trace::{generate, total_psums, TraceConfig};

#[test]
fn cnn_inference_on_simulated_hw_is_bit_exact_vs_golden() {
    let net = EdgeCnn::new(42);
    let first = net.specs()[0];
    let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
    for seed in 0..5u64 {
        let img = EdgeCnn::sample_input(seed, &first);
        assert!(
            sched.verify_against_golden(&img).unwrap(),
            "seed {seed}: hw-sim logits diverge from golden"
        );
    }
}

#[test]
fn layer_chaining_saves_dma_cycles() {
    let net = EdgeCnn::new(1);
    let first = net.specs()[0];
    let img = EdgeCnn::sample_input(1, &first);
    let mut sched = CnnScheduler::new(IpCoreConfig::default(), net);
    let run = sched.infer(&img).unwrap();
    let saving = 1.0 - run.total_cycles as f64 / run.total_cycles_dma_roundtrip as f64;
    assert!(saving > 0.05, "chaining saves {saving:.3} (>5% expected)");
}

#[test]
fn mixed_trace_through_coordinator_completes_and_scales() {
    let trace = generate(&TraceConfig {
        n: 48,
        mean_gap_us: 0,
        s52_fraction: 0.0,
        depthwise_fraction: 0.0,
        seed: 77,
    });
    let mut one = Server::new(CoordinatorConfig::default().with_cores(1));
    let r1 = one.run_trace(&trace);
    one.shutdown();
    let mut four = Server::new(CoordinatorConfig::default().with_cores(4));
    let r4 = four.run_trace(&trace);
    four.shutdown();

    assert_eq!(r1.n_requests, 48);
    assert_eq!(r4.n_requests, 48);
    assert_eq!(r1.total_psums, total_psums(&trace));
    assert_eq!(r4.total_psums, r1.total_psums);
    // Simulated hardware throughput must not degrade with more cores.
    assert!(r4.sim_gops_psum >= r1.sim_gops_psum * 0.99);
}

#[test]
fn burst_of_same_shape_amortises_weight_dma() {
    let entry = generate(&TraceConfig {
        n: 1,
        s52_fraction: 0.0,
        ..Default::default()
    });
    let trace: Vec<_> = entry.into_iter().cycle().take(16).collect();
    let mut server = Server::new(CoordinatorConfig::default());
    let report = server.run_trace(&trace);
    server.shutdown();
    assert!(
        report.weight_dma_skip_rate >= 0.75,
        "skip rate {:.2}",
        report.weight_dma_skip_rate
    );
}

#[test]
fn heterogeneous_pool_serves_depthwise_traffic_end_to_end() {
    // The acceptance scenario for the backend refactor: a mixed pool
    // (simulated IP cores + golden-CPU fallback workers) serves a trace
    // with depthwise traffic; everything is answered exactly once and
    // the PSUM accounting is kind-aware on both sides.
    let trace = generate(&TraceConfig {
        n: 40,
        mean_gap_us: 0,
        s52_fraction: 0.0,
        depthwise_fraction: 0.35,
        seed: 88,
    });
    let mut server = Server::new(
        CoordinatorConfig::default().with_cores(3).with_golden_workers(2),
    );
    let report = server.run_trace(&trace);
    server.shutdown();
    assert_eq!(report.n_requests, 40);
    assert_eq!(report.n_cores, 5);
    assert_eq!(report.total_psums, total_psums(&trace));
    let served: usize = report.backend_mix.iter().map(|(_, n)| n).sum();
    assert_eq!(served, 40);
    assert!(
        report.backend_mix.iter().any(|(name, _)| *name == "sim-ipcore-i32"),
        "mix {:?}",
        report.backend_mix
    );
}

#[test]
fn throughput_report_is_consistent() {
    let trace = generate(&TraceConfig {
        n: 8,
        s52_fraction: 0.25,
        ..Default::default()
    });
    let mut server = Server::new(CoordinatorConfig::default().with_cores(2));
    let report = server.run_trace(&trace);
    server.shutdown();
    assert!(report.sim_gops_psum > 0.0);
    assert!(report.p50_us <= report.p99_us);
    assert!(report.host_rps > 0.0);
}
