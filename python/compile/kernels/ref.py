"""Pure-jnp / numpy correctness oracles for the convolution kernels.

Two oracles, matching the two accumulator modes of the hardware
(DESIGN.md §5):

* :func:`conv3x3_ref` — the *mathematical* convolution the Pallas kernel
  must match: wide (f32/i32) accumulation, valid padding, NCHW layout.
  Written with explicit window slicing (no ``lax.conv``) so it is an
  independent oracle, not a re-statement of the implementation.
* :func:`conv3x3_wrap8` — the *silicon* semantics of the paper's Fig. 6
  waveform: uint8 data, PSUMs wrap modulo 256. numpy, bit-exact.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

KH = KW = 3  # the paper's core is fixed-function 3x3


def conv3x3_ref(img, w, bias=None, relu=False):
    """Valid 3x3 convolution, wide accumulation.

    Args:
      img:  ``(C, H, W)`` input feature map.
      w:    ``(K, C, 3, 3)`` kernels.
      bias: optional ``(K,)`` bias, pre-added exactly like the paper's
            output-BRAM initialisation.
      relu: apply ReLU to the result.

    Returns:
      ``(K, H-2, W-2)`` feature map.
    """
    c, h, width = img.shape
    k, wc, kh, kw = w.shape
    assert wc == c and kh == KH and kw == KW, (img.shape, w.shape)
    oh, ow = h - KH + 1, width - KW + 1
    out = jnp.zeros((k, oh, ow), dtype=jnp.promote_types(img.dtype, w.dtype))
    for dy in range(KH):
        for dx in range(KW):
            # (C, OH, OW) window slab for this tap.
            slab = img[:, dy : dy + oh, dx : dx + ow]
            # (K, C) tap weights contract against the channel axis.
            out = out + jnp.einsum("kc,cij->kij", w[:, :, dy, dx], slab)
    if bias is not None:
        out = out + bias[:, None, None]
    if relu:
        out = jnp.maximum(out, 0)
    return out


def conv3x3_wrap8(img: np.ndarray, w: np.ndarray, bias=None) -> np.ndarray:
    """Bit-exact Fig. 6 semantics: uint8 inputs, PSUM wraps mod 256.

    This is what the synthesised Verilog computes (the waveform's 8-bit
    ``psum_*`` signals prove the accumulator is 8 bits wide).
    """
    img = np.asarray(img, dtype=np.uint8)
    w = np.asarray(w, dtype=np.uint8)
    c, h, width = img.shape
    k = w.shape[0]
    oh, ow = h - KH + 1, width - KW + 1
    out = np.zeros((k, oh, ow), dtype=np.uint8)
    if bias is not None:
        out = out + np.asarray(bias, dtype=np.uint8)[:, None, None]
    for ki in range(k):
        for ci in range(c):
            for y in range(oh):
                for x in range(ow):
                    acc = int(out[ki, y, x])
                    for dy in range(KH):
                        for dx in range(KW):
                            acc = (acc + int(img[ci, y + dy, x + dx]) * int(w[ki, ci, dy, dx])) & 0xFF
                    out[ki, y, x] = acc
    return out


def maxpool2x2_ref(img):
    """2x2/stride-2 max pool, NCHW, floor semantics (odd trailing row/col dropped)."""
    c, h, w = img.shape
    img = img[:, : h // 2 * 2, : w // 2 * 2]
    return jnp.max(
        jnp.stack(
            [
                img[:, 0::2, 0::2],
                img[:, 0::2, 1::2],
                img[:, 1::2, 0::2],
                img[:, 1::2, 1::2],
            ]
        ),
        axis=0,
    )
