//! TCP front-end: newline-delimited JSON over a socket — the network
//! face an edge gateway actually talks to, in front of the same
//! batcher + core pool the in-process server uses.
//!
//! Wire protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"id":1,"spec":{"c":8,"h":16,"w":16,"k":8},"seed":42}
//! -> {"id":2,"spec":{...},"img":[...C*H*W u8...],
//!     "weights":[...K*C*9 u8...],"bias":[...K i32...]}
//! <- {"id":1,"ok":true,"core":0,"compute_cycles":6272,
//!     "sim_us":56,"output_head":[...,8],"checksum":1234567}
//! <- {"id":9,"ok":false,"error":"..."}
//! ```
//!
//! `seed` requests synthesise deterministic tensors server-side (good
//! for load generation); explicit-tensor requests carry real data. The
//! checksum (sum of output words mod 2^31) lets load generators verify
//! numerics without shipping whole feature maps back.

use super::dispatch::CorePool;
use super::request::{weights_fingerprint_salted, ConvJob, ConvResult, Submission};
use crate::backend::JobKind;
use crate::model::{LayerSpec, Tensor};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

/// Running TCP server handle.
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    listener_thread: std::thread::JoinHandle<()>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

fn parse_spec(j: &Json) -> Result<LayerSpec, String> {
    let g = |k: &str| {
        j.get(&[k])
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("spec.{k} missing"))
    };
    let mut spec = LayerSpec::new(g("c")?, g("h")?, g("w")?, g("k")?);
    if j.get(&["relu"]).and_then(Json::as_bool).unwrap_or(false) {
        spec = spec.with_relu();
    }
    Ok(spec)
}

fn parse_u8_array(j: &Json, want_len: usize, name: &str) -> Result<Vec<u8>, String> {
    let arr = j.as_arr().ok_or_else(|| format!("{name} must be an array"))?;
    if arr.len() != want_len {
        return Err(format!("{name} length {} != {want_len}", arr.len()));
    }
    arr.iter()
        .map(|v| {
            v.as_f64()
                .filter(|n| (0.0..=255.0).contains(n))
                .map(|n| n as u8)
                .ok_or_else(|| format!("{name} element out of u8 range"))
        })
        .collect()
}

/// Build a ConvJob from one request line.
fn job_from_request(id: u64, req: &Json) -> Result<ConvJob, String> {
    let spec = parse_spec(req.get(&["spec"]).ok_or("missing spec")?)?;
    if !spec.paper_compatible() {
        return Err(format!("spec violates §4.1 (K%4!=0 or too small): {spec:?}"));
    }
    if let Some(img_j) = req.get(&["img"]) {
        let img = parse_u8_array(img_j, spec.c * spec.h * spec.w, "img")?;
        let wts = parse_u8_array(
            req.get(&["weights"]).ok_or("missing weights")?,
            spec.k * spec.c * 9,
            "weights",
        )?;
        let bias_arr = req
            .get(&["bias"])
            .and_then(Json::as_arr)
            .ok_or("missing bias")?;
        if bias_arr.len() != spec.k {
            return Err(format!("bias length {} != {}", bias_arr.len(), spec.k));
        }
        let bias: Vec<i32> = bias_arr
            .iter()
            .map(|v| v.as_f64().map(|n| n as i32).ok_or("bias element"))
            .collect::<Result<_, _>>()?;
        Ok(ConvJob {
            id,
            spec,
            kind: JobKind::Standard,
            // The wire protocol serves production traffic only; wrap-8
            // replies stay an in-process (experiment) concern.
            accum: crate::hw::AccumMode::I32,
            img: Tensor::from_vec(&[spec.c, spec.h, spec.w], img),
            weights: Tensor::from_vec(&[spec.k, spec.c, 3, 3], wts),
            bias,
            // Explicit tensors: a unique weight set per request; the id
            // is hashed into the fingerprint (not XOR-ed) so no id can
            // alias a synthetic per-spec weight set.
            weights_id: weights_fingerprint_salted(&spec, JobKind::Standard, id),
        })
    } else {
        let seed = req
            .get(&["seed"])
            .and_then(Json::as_f64)
            .ok_or("need seed or img/weights/bias")? as u64;
        Ok(ConvJob::synthetic(id, spec, seed))
    }
}

fn response_json(r: &ConvResult, freq_hz: u64) -> Json {
    let head: Vec<i64> = r.output.data().iter().take(8).map(|&v| v as i64).collect();
    let checksum = r
        .output
        .data()
        .iter()
        .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
    Json::obj(vec![
        ("id", Json::num(r.id as f64)),
        ("ok", Json::Bool(true)),
        ("core", Json::num(r.core as f64)),
        ("backend", Json::str(r.backend)),
        ("compute_cycles", Json::num(r.cycles.compute as f64)),
        (
            "sim_us",
            Json::num((r.cycles.total as f64 / freq_hz as f64 * 1e6).round()),
        ),
        ("weights_reused", Json::Bool(r.weights_reused)),
        ("output_head", Json::arr_i64(head)),
        ("checksum", Json::num(checksum as f64)),
    ])
}

fn error_json(id: u64, msg: &str) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

fn handle_connection(stream: TcpStream, pool: Arc<CorePool>, next_id: Arc<AtomicU64>) {
    let freq = pool.ip_config().freq_hz;
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let reply = match Json::parse(&line) {
            Err(e) => error_json(id, &format!("bad json: {e}")),
            Ok(req) => {
                let req_id = req
                    .get(&["id"])
                    .and_then(Json::as_f64)
                    .map(|n| n as u64)
                    .unwrap_or(id);
                match job_from_request(req_id, &req) {
                    Err(e) => error_json(req_id, &e),
                    Ok(job) => {
                        let (tx, rx) = channel();
                        let spec = job.spec;
                        let weights_id = job.weights_id;
                        let kind = job.kind;
                        let accum = job.accum;
                        pool.dispatch(super::batcher::Batch {
                            spec,
                            weights_id,
                            kind,
                            accum,
                            jobs: vec![Submission {
                                job,
                                reply: tx,
                                enqueued: std::time::Instant::now(),
                            }],
                        });
                        match rx.recv() {
                            Ok(result) => response_json(&result, freq),
                            Err(_) => error_json(req_id, "worker dropped"),
                        }
                    }
                }
            }
        };
        if writeln!(writer, "{}", reply.to_json()).is_err() {
            break;
        }
    }
    let _ = peer; // connection closed
}

impl TcpServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn start(addr: &str, n_cores: usize, ip: crate::hw::IpCoreConfig) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let pool = Arc::new(CorePool::new(n_cores, ip));
        let next_id = Arc::new(AtomicU64::new(1));
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let shutdown_flag = Arc::clone(&shutdown);
        listener.set_nonblocking(true)?;
        let listener_thread = std::thread::Builder::new()
            .name("repro-tcp".into())
            .spawn(move || {
                loop {
                    if shutdown_flag.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            let pool = Arc::clone(&pool);
                            let next_id = Arc::clone(&next_id);
                            std::thread::spawn(move || handle_connection(stream, pool, next_id));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(TcpServer {
            addr: local,
            listener_thread,
            shutdown,
        })
    }

    /// Stop accepting connections (in-flight requests drain).
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = self.listener_thread.join();
    }
}

/// Blocking one-shot client (used by tests, examples and `repro client`).
pub fn request_once(addr: &std::net::SocketAddr, body: &Json) -> anyhow::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", body.to_json())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::IpCoreConfig;
    use crate::model::{golden, QUICKSTART};

    fn start() -> TcpServer {
        TcpServer::start("127.0.0.1:0", 2, IpCoreConfig::default()).expect("bind")
    }

    #[test]
    fn seed_request_round_trips() {
        let server = start();
        let req = Json::parse(
            r#"{"id":7,"spec":{"c":8,"h":16,"w":16,"k":8},"seed":42}"#,
        )
        .unwrap();
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true));
        assert_eq!(resp.get(&["id"]).unwrap().as_usize(), Some(7));
        assert_eq!(
            resp.get(&["compute_cycles"]).unwrap().as_usize(),
            Some(6272)
        );
        // Checksum matches a local recomputation of the same seed.
        let job = ConvJob::synthetic(7, QUICKSTART, 42);
        let want = golden::conv3x3_i32(&job.img, &job.weights, &job.bias, false);
        let checksum = want
            .data()
            .iter()
            .fold(0i64, |a, &v| (a + v as i64) & 0x7FFF_FFFF);
        assert_eq!(
            resp.get(&["checksum"]).unwrap().as_f64(),
            Some(checksum as f64)
        );
        server.stop();
    }

    #[test]
    fn explicit_tensor_request_computes() {
        let server = start();
        // 1-channel 4x4 image, 4 kernels: small enough to inline.
        let img: Vec<u64> = (0..16).collect();
        let wts: Vec<u64> = (0..36).map(|i| i % 5).collect();
        let req = Json::obj(vec![
            ("id", Json::num(1u32)),
            (
                "spec",
                Json::obj(vec![
                    ("c", Json::num(1u32)),
                    ("h", Json::num(4u32)),
                    ("w", Json::num(4u32)),
                    ("k", Json::num(4u32)),
                ]),
            ),
            ("img", Json::arr_u64(img.clone())),
            ("weights", Json::arr_u64(wts.clone())),
            ("bias", Json::arr_i64([0, 0, 0, 0])),
        ]);
        let resp = request_once(&server.addr, &req).unwrap();
        assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true), "{resp:?}");
        // Verify output head against golden.
        let img_t = Tensor::from_vec(&[1, 4, 4], img.iter().map(|&v| v as u8).collect());
        let wts_t = Tensor::from_vec(&[4, 1, 3, 3], wts.iter().map(|&v| v as u8).collect());
        let want = golden::conv3x3_i32(&img_t, &wts_t, &[0; 4], false);
        let head = resp.get(&["output_head"]).unwrap().as_arr().unwrap();
        for (a, b) in head.iter().zip(want.data()) {
            assert_eq!(a.as_f64().unwrap() as i32, *b);
        }
        server.stop();
    }

    #[test]
    fn bad_requests_get_errors_not_disconnects() {
        let server = start();
        for bad in [
            "not json at all",
            r#"{"id":1}"#,
            r#"{"id":2,"spec":{"c":4,"h":8,"w":8,"k":6},"seed":1}"#, // K%4
            r#"{"id":3,"spec":{"c":1,"h":4,"w":4,"k":4},"img":[1,2,3]}"#, // short
        ] {
            let mut stream = TcpStream::connect(server.addr).unwrap();
            writeln!(stream, "{bad}").unwrap();
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(false), "{bad}");
            assert!(resp.get(&["error"]).is_some());
        }
        server.stop();
    }

    #[test]
    fn multiple_requests_per_connection() {
        let server = start();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        for i in 0..3 {
            writeln!(
                stream,
                r#"{{"id":{i},"spec":{{"c":4,"h":8,"w":8,"k":4}},"seed":{i}}}"#
            )
            .unwrap();
        }
        let reader = BufReader::new(stream.try_clone().unwrap());
        let mut seen = Vec::new();
        for line in reader.lines().take(3) {
            let resp = Json::parse(&line.unwrap()).unwrap();
            assert_eq!(resp.get(&["ok"]).unwrap().as_bool(), Some(true));
            seen.push(resp.get(&["id"]).unwrap().as_usize().unwrap());
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2]);
        drop(stream);
        server.stop();
    }
}
