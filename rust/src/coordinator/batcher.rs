//! Shape batcher: groups pending jobs by layer spec + weight set.
//!
//! Why batch at all? The IP core is weight-stationary *within* a sweep;
//! consecutive jobs that share a weight set also share the weight BRAM
//! contents, so the dispatcher can skip the weight DMA for all but the
//! first job of a batch. Same-shape grouping additionally keeps the
//! controller's configure phase trivial (no dimension reprogramming).
//!
//! The policy is deliberately simple and *fair*: FIFO across batches,
//! a batch closes at `max_batch`, and a partial batch cannot be
//! overtaken more than `max_skips` times (no starvation).

use super::config::BatchConfig;
use super::request::Submission;
use crate::backend::JobKind;
use crate::hw::AccumMode;
use crate::model::LayerSpec;
use std::collections::VecDeque;

/// A closed batch, ready for dispatch. All jobs share spec, weight set,
/// kind and required accumulator mode, so a batch routes as one unit to
/// one capable backend.
#[derive(Debug)]
pub struct Batch {
    pub spec: LayerSpec,
    pub weights_id: u64,
    pub kind: JobKind,
    /// Accumulator semantics every job in the batch requires of its
    /// reply (part of the grouping key: wrap-8 and production jobs of
    /// the same shape must not share a batch, they route differently).
    pub accum: AccumMode,
    pub jobs: Vec<Submission>,
}

/// Accumulates submissions into batches.
#[derive(Debug)]
pub struct Batcher {
    config: BatchConfig,
    /// Open batches in arrival order of their first job.
    open: VecDeque<(Batch, usize)>, // (batch, times_skipped)
}

impl Batcher {
    pub fn new(config: BatchConfig) -> Self {
        Batcher {
            config,
            open: VecDeque::new(),
        }
    }

    /// Add a submission; returns any batch that closed as a result.
    pub fn push(&mut self, sub: Submission) -> Vec<Batch> {
        let key = (sub.job.spec, sub.job.weights_id, sub.job.kind, sub.job.accum);
        let mut closed = Vec::new();

        // Try to join an open batch; count skips on the ones passed over.
        let mut sub = Some(sub);
        for (batch, skips) in self.open.iter_mut() {
            if (batch.spec, batch.weights_id, batch.kind, batch.accum) == key
                && batch.jobs.len() < self.config.max_batch
            {
                batch.jobs.push(sub.take().expect("joined at most once"));
                break;
            } else {
                *skips += 1;
            }
        }
        if let Some(sub) = sub {
            self.open.push_back((
                Batch {
                    spec: key.0,
                    weights_id: key.1,
                    kind: key.2,
                    accum: key.3,
                    jobs: vec![sub],
                },
                0,
            ));
        }

        // Close: full batches, and starved partial batches.
        let max_batch = self.config.max_batch;
        let max_skips = self.config.max_skips;
        while let Some(pos) = self
            .open
            .iter()
            .position(|(b, s)| b.jobs.len() >= max_batch || *s >= max_skips)
        {
            let (batch, _) = self.open.remove(pos).unwrap();
            closed.push(batch);
        }
        closed
    }

    /// Flush everything (idle timeout / shutdown).
    pub fn flush(&mut self) -> Vec<Batch> {
        self.open.drain(..).map(|(b, _)| b).collect()
    }

    pub fn pending(&self) -> usize {
        self.open.iter().map(|(b, _)| b.jobs.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ConvJob;
    use crate::model::{LayerSpec, QUICKSTART, S52};
    use std::sync::mpsc::channel;

    fn sub(id: u64, spec: LayerSpec) -> Submission {
        let (tx, _rx) = channel();
        Submission {
            job: ConvJob::synthetic(id, spec, id),
            reply: tx,
            enqueued: std::time::Instant::now(),
        }
    }

    fn cfg(max_batch: usize, max_skips: usize) -> BatchConfig {
        BatchConfig {
            max_batch,
            max_skips,
        }
    }

    #[test]
    fn same_shape_fills_one_batch() {
        let mut b = Batcher::new(cfg(3, 100));
        assert!(b.push(sub(1, QUICKSTART)).is_empty());
        assert!(b.push(sub(2, QUICKSTART)).is_empty());
        let closed = b.push(sub(3, QUICKSTART));
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].jobs.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn mixed_shapes_open_separate_batches() {
        let mut b = Batcher::new(cfg(4, 100));
        b.push(sub(1, QUICKSTART));
        b.push(sub(2, S52));
        assert_eq!(b.pending(), 2);
        let flushed = b.flush();
        assert_eq!(flushed.len(), 2);
        assert!(flushed.iter().all(|batch| batch.jobs.len() == 1));
    }

    #[test]
    fn starved_partial_batch_closes() {
        let mut b = Batcher::new(cfg(8, 2));
        b.push(sub(1, QUICKSTART)); // partial batch
        b.push(sub(2, S52)); // skip 1
        let closed = b.push(sub(3, S52)); // skip 2 -> quickstart batch must close
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].spec, QUICKSTART);
    }

    #[test]
    fn depthwise_and_standard_of_same_spec_never_share_a_batch() {
        // 4x8x8 k4 is a valid shape for both kinds; the batch key must
        // keep them apart so a batch routes to one capable backend.
        let spec = LayerSpec::new(4, 8, 8, 4);
        let mut b = Batcher::new(cfg(8, 100));
        let (tx, _rx) = channel();
        for i in 0..4u64 {
            let job = if i % 2 == 0 {
                ConvJob::synthetic(i, spec, i)
            } else {
                ConvJob::synthetic_depthwise(i, spec, i)
            };
            b.push(Submission {
                job,
                reply: tx.clone(),
                enqueued: std::time::Instant::now(),
            });
        }
        let batches = b.flush();
        assert_eq!(batches.len(), 2);
        for batch in &batches {
            assert!(batch.jobs.iter().all(|s| s.job.kind == batch.kind));
        }
    }

    #[test]
    fn accum_modes_never_share_a_batch() {
        // Wrap-8 and production jobs of the same spec route to different
        // backends, so the batcher must keep them apart.
        let mut b = Batcher::new(cfg(8, 100));
        let (tx, _rx) = channel();
        for i in 0..6u64 {
            let mut job = ConvJob::synthetic(i, QUICKSTART, i);
            if i % 2 == 1 {
                job = job.with_accum(AccumMode::Wrap8);
            }
            b.push(Submission {
                job,
                reply: tx.clone(),
                enqueued: std::time::Instant::now(),
            });
        }
        let batches = b.flush();
        assert_eq!(batches.len(), 2);
        for batch in &batches {
            assert!(batch.jobs.iter().all(|s| s.job.accum == batch.accum));
        }
    }

    #[test]
    fn batch_never_mixes_specs() {
        let mut b = Batcher::new(cfg(2, 100));
        let mut all = Vec::new();
        for i in 0..10 {
            let spec = if i % 2 == 0 { QUICKSTART } else { S52 };
            all.extend(b.push(sub(i, spec)));
        }
        all.extend(b.flush());
        for batch in &all {
            assert!(batch.jobs.iter().all(|s| s.job.spec == batch.spec));
        }
        let total: usize = all.iter().map(|b| b.jobs.len()).sum();
        assert_eq!(total, 10, "every request in exactly one batch");
    }
}
