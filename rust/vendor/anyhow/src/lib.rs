//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored
//! crate provides the exact subset of the `anyhow` 1.x API the
//! repository uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`]
//! and [`ensure!`] macros, and the blanket `From<E: std::error::Error>`
//! conversion that makes `?` work. Error chains and backtraces are out
//! of scope; `Error` carries the boxed source (or a message) and
//! renders it through `Display`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what keeps the blanket `From` impl
//! coherent.

use std::fmt;

/// Boxed dynamic error with a `Display`-first rendering.
pub struct Error {
    inner: Box<dyn std::error::Error + Send + Sync + 'static>,
}

/// `Result` defaulted to [`Error`], as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Internal carrier for string-built errors ([`anyhow!`]).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MessageError {}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            inner: Box::new(MessageError(message.to_string())),
        }
    }

    /// Wrap a concrete error value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Error {
            inner: Box::new(error),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a format string, a displayable value, or
/// `format!`-style arguments — mirrors `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error — mirrors `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Assert a condition, early-returning an error when it fails —
/// mirrors `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}",
                ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    fn guarded(v: i32) -> Result<i32> {
        ensure!(v > 0, "value {v} must be positive");
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn anyhow_macro_formats() {
        let name = "probe";
        let e = anyhow!("unknown variant '{name}'");
        assert_eq!(e.to_string(), "unknown variant 'probe'");
        let e = anyhow!("at {}: {name}", 7);
        assert_eq!(e.to_string(), "at 7: probe");
        let e = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn ensure_and_bail_return_errors() {
        assert_eq!(guarded(3).unwrap(), 3);
        let e = guarded(-1).unwrap_err();
        assert!(e.to_string().contains("must be positive"));
    }

    #[test]
    fn debug_renders_message() {
        let e = anyhow!("boom");
        assert_eq!(format!("{e:?}"), "boom");
    }
}
