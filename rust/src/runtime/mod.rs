//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from JAX + Pallas) and executes
//! them on the XLA CPU client. Python is never on this path.
//!
//! * [`artifacts`] — parses `manifest.json` (via [`crate::util::json`])
//!   into a registry keyed by the layer-spec name shared with
//!   `python/compile/model.py`.
//! * [`executor`] — PJRT client + compiled-executable cache; converts
//!   between [`crate::model::Tensor`] and `xla::Literal`.

pub mod artifacts;
pub mod executor;

pub use artifacts::{ArtifactRegistry, Variant};
pub use executor::XlaRuntime;
