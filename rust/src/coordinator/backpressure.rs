//! Admission control / backpressure for the serving path.
//!
//! The simulated IP cores are a fixed-capacity resource; an open-loop
//! client can queue unbounded work and blow latency through the roof.
//! The admission controller bounds *in-flight simulated work* (measured
//! in PSUMs, the same unit the dispatcher balances by) and offers the
//! two standard policies: reject-on-full (load shedding, the serving
//! answer) and block-until-drained (batch/offline answer).
//!
//! Blocked submitters are never wedged forever: [`AdmissionController::
//! shutdown`] wakes them all with `Rejected` (a stopping server must
//! not hang its clients), and [`AdmissionController::admit_deadline`]
//! bounds an individual wait.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// What to do when the in-flight budget is exhausted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Refuse new work immediately (caller sees `Rejected`).
    Reject,
    /// Block the submitting thread until capacity frees up.
    Block,
}

/// Admission decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    Rejected,
}

#[derive(Debug)]
struct State {
    inflight: u64,
    /// Once set, every admit — current waiters included — returns
    /// `Rejected`. Lives under the same mutex as `inflight` so a
    /// shutdown signal can never race a waiter back to sleep.
    shutting_down: bool,
}

/// Bounded in-flight work counter.
#[derive(Debug)]
pub struct AdmissionController {
    max_inflight_psums: u64,
    state: Mutex<State>,
    freed: Condvar,
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
}

impl AdmissionController {
    pub fn new(max_inflight_psums: u64) -> Self {
        AdmissionController {
            max_inflight_psums,
            state: Mutex::new(State {
                inflight: 0,
                shutting_down: false,
            }),
            freed: Condvar::new(),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// Try to admit `psums` of work under `policy`.
    pub fn admit(&self, psums: u64, policy: Policy) -> Admission {
        self.admit_inner(psums, policy, None)
    }

    /// [`Policy::Block`] admit that waits at most `deadline` before
    /// giving up with `Rejected` — for submitters that cannot afford to
    /// park forever behind a wedged pool.
    pub fn admit_deadline(&self, psums: u64, deadline: Duration) -> Admission {
        self.admit_inner(psums, Policy::Block, Some(deadline))
    }

    fn admit_inner(&self, psums: u64, policy: Policy, deadline: Option<Duration>) -> Admission {
        let start = Instant::now();
        let mut state = self.state.lock().expect("admission lock");
        loop {
            if state.shutting_down {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return Admission::Rejected;
            }
            // A single oversized job is admitted when idle rather than
            // deadlocking forever.
            let fits = state.inflight + psums <= self.max_inflight_psums
                || (state.inflight == 0 && psums > self.max_inflight_psums);
            if fits {
                state.inflight += psums;
                self.admitted.fetch_add(1, Ordering::Relaxed);
                return Admission::Admitted;
            }
            match policy {
                Policy::Reject => {
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    return Admission::Rejected;
                }
                Policy::Block => match deadline {
                    None => {
                        state = self.freed.wait(state).expect("admission wait");
                    }
                    Some(d) => {
                        let Some(remaining) = d.checked_sub(start.elapsed()) else {
                            self.rejected.fetch_add(1, Ordering::Relaxed);
                            return Admission::Rejected;
                        };
                        let (s, _timed_out) = self
                            .freed
                            .wait_timeout(state, remaining)
                            .expect("admission wait");
                        // Loop re-checks capacity, shutdown and the
                        // deadline — a timed-out wake that finds
                        // capacity still admits.
                        state = s;
                    }
                },
            }
        }
    }

    /// Mark `psums` of admitted work complete.
    pub fn complete(&self, psums: u64) {
        let mut state = self.state.lock().expect("admission lock");
        state.inflight = state.inflight.saturating_sub(psums);
        drop(state);
        self.freed.notify_all();
    }

    /// Wake every blocked submitter with `Rejected` and refuse all
    /// further work — a stopping server must not wedge its clients on a
    /// Condvar that will never signal again.
    pub fn shutdown(&self) {
        let mut state = self.state.lock().expect("admission lock");
        state.shutting_down = true;
        drop(state);
        self.freed.notify_all();
    }

    pub fn inflight(&self) -> u64 {
        self.state.lock().expect("admission lock").inflight
    }

    pub fn capacity(&self) -> u64 {
        self.max_inflight_psums
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn admits_within_budget() {
        let ac = AdmissionController::new(100);
        assert_eq!(ac.admit(60, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.admit(40, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.inflight(), 100);
    }

    #[test]
    fn rejects_over_budget() {
        let ac = AdmissionController::new(100);
        assert_eq!(ac.admit(80, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.admit(30, Policy::Reject), Admission::Rejected);
        assert_eq!(ac.rejected.load(Ordering::Relaxed), 1);
        ac.complete(80);
        assert_eq!(ac.admit(30, Policy::Reject), Admission::Admitted);
    }

    #[test]
    fn oversized_job_admitted_when_idle() {
        let ac = AdmissionController::new(10);
        assert_eq!(ac.admit(1000, Policy::Reject), Admission::Admitted);
        assert_eq!(ac.admit(1, Policy::Reject), Admission::Rejected);
        ac.complete(1000);
        assert_eq!(ac.admit(1, Policy::Reject), Admission::Admitted);
    }

    #[test]
    fn block_policy_waits_for_completion() {
        let ac = Arc::new(AdmissionController::new(50));
        assert_eq!(ac.admit(50, Policy::Block), Admission::Admitted);
        let ac2 = Arc::clone(&ac);
        let waiter = std::thread::spawn(move || ac2.admit(20, Policy::Block));
        std::thread::sleep(Duration::from_millis(50));
        assert!(!waiter.is_finished(), "submitter must be blocked");
        ac.complete(50);
        assert_eq!(waiter.join().unwrap(), Admission::Admitted);
        assert_eq!(ac.inflight(), 20);
    }

    #[test]
    fn shutdown_wakes_blocked_submitters() {
        // The satellite bug: Block waited on a Condvar with no shutdown
        // signal, so a stopping server wedged its submitters forever.
        let ac = Arc::new(AdmissionController::new(50));
        assert_eq!(ac.admit(50, Policy::Block), Admission::Admitted);
        let ac2 = Arc::clone(&ac);
        let waiter = std::thread::spawn(move || ac2.admit(20, Policy::Block));
        std::thread::sleep(Duration::from_millis(50));
        assert!(!waiter.is_finished(), "submitter must be blocked");
        ac.shutdown();
        assert_eq!(waiter.join().unwrap(), Admission::Rejected);
        // After shutdown nothing is admitted, even with capacity free.
        ac.complete(50);
        assert_eq!(ac.admit(1, Policy::Block), Admission::Rejected);
    }

    #[test]
    fn admit_deadline_gives_up_in_bounded_time() {
        let ac = AdmissionController::new(10);
        assert_eq!(ac.admit(10, Policy::Block), Admission::Admitted);
        let t0 = Instant::now();
        assert_eq!(
            ac.admit_deadline(5, Duration::from_millis(50)),
            Admission::Rejected
        );
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "deadline admit must not wedge"
        );
        assert_eq!(ac.inflight(), 10, "rejected work is not charged");
    }

    #[test]
    fn admit_deadline_admits_when_capacity_frees_in_time() {
        let ac = Arc::new(AdmissionController::new(10));
        assert_eq!(ac.admit(10, Policy::Block), Admission::Admitted);
        let ac2 = Arc::clone(&ac);
        let releaser = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            ac2.complete(10);
        });
        assert_eq!(
            ac.admit_deadline(5, Duration::from_secs(30)),
            Admission::Admitted
        );
        releaser.join().unwrap();
        assert_eq!(ac.inflight(), 5);
    }

    #[test]
    fn complete_never_underflows() {
        let ac = AdmissionController::new(10);
        ac.complete(99);
        assert_eq!(ac.inflight(), 0);
    }

    #[test]
    fn concurrent_admissions_respect_budget() {
        let ac = Arc::new(AdmissionController::new(100));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let ac = Arc::clone(&ac);
            handles.push(std::thread::spawn(move || {
                let mut admitted = 0;
                for _ in 0..50 {
                    if ac.admit(10, Policy::Reject) == Admission::Admitted {
                        admitted += 1;
                        std::thread::yield_now();
                        ac.complete(10);
                    }
                }
                admitted
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0);
        assert_eq!(ac.inflight(), 0);
    }
}
