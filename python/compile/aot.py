"""AOT lowering: JAX (L2+L1) -> HLO text artifacts + manifest for rust.

Run once at build time (`make artifacts`). Emits, into ``artifacts/``:

* ``<variant>.hlo.txt`` — one per layer shape in ``model.VARIANTS``;
* ``edge_cnn.hlo.txt``  — the whole edge CNN as a single fused module;
* ``manifest.json``     — shapes/flags for every artifact, the rust
  runtime's registry (`runtime::artifacts`);
* ``model.hlo.txt``     — the quickstart variant, doubling as the
  Makefile's freshness sentinel.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_layer(spec: model.ConvSpec) -> str:
    img = jax.ShapeDtypeStruct((spec.c, spec.h, spec.w), jnp.float32)
    w = jax.ShapeDtypeStruct((spec.k, spec.c, 3, 3), jnp.float32)
    b = jax.ShapeDtypeStruct((spec.k,), jnp.float32)
    return to_hlo_text(jax.jit(model.layer_fn(spec)).lower(img, w, b))


def lower_edge_cnn() -> str:
    first = model.EDGE_CNN[0]
    img = jax.ShapeDtypeStruct((first.c, first.h, first.w), jnp.float32)
    params = model.edge_cnn_params_specs()
    return to_hlo_text(jax.jit(model.cnn_forward).lower(img, *params))


def manifest_entry(spec: model.ConvSpec) -> dict:
    return {
        "kind": "conv_layer",
        "file": f"{spec.name}.hlo.txt",
        "inputs": [[spec.c, spec.h, spec.w], [spec.k, spec.c, 3, 3], [spec.k]],
        "output": [spec.k, spec.oh, spec.ow],
        "c": spec.c,
        "h": spec.h,
        "w": spec.w,
        "k": spec.k,
        "relu": spec.relu,
        "pool": spec.pool,
        "macs": spec.macs,
        "psums": spec.psums,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="sentinel path; artifacts land in its directory")
    args = ap.parse_args()
    sentinel = pathlib.Path(args.out)
    outdir = sentinel.parent
    outdir.mkdir(parents=True, exist_ok=True)

    manifest: dict = {"format": "hlo-text", "dtype": "f32", "variants": {}}
    for spec in model.VARIANTS:
        text = lower_layer(spec)
        (outdir / f"{spec.name}.hlo.txt").write_text(text)
        manifest["variants"][spec.name] = manifest_entry(spec)
        print(f"  {spec.name}: {len(text)} chars")

    cnn_text = lower_edge_cnn()
    (outdir / "edge_cnn.hlo.txt").write_text(cnn_text)
    first = model.EDGE_CNN[0]
    manifest["variants"]["edge_cnn"] = {
        "kind": "cnn",
        "file": "edge_cnn.hlo.txt",
        "inputs": [[first.c, first.h, first.w]]
        + [list(s.shape) for s in model.edge_cnn_params_specs()],
        "output": [model.EDGE_CNN[-1].k],
        "layers": [s.name for s in model.EDGE_CNN],
    }
    print(f"  edge_cnn: {len(cnn_text)} chars")

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    # Sentinel: quickstart variant under the Makefile's expected name.
    sentinel.write_text((outdir / f"{model.QUICKSTART.name}.hlo.txt").read_text())
    print(f"wrote {len(manifest['variants'])} variants + manifest to {outdir}")


if __name__ == "__main__":
    main()
