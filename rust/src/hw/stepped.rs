//! Cycle-stepped microarchitecture model of one computing core.
//!
//! The fast functional model ([`super::compute_core`]) charges 8 cycles
//! per window by fiat — the paper's §5.2 claim. This module *derives*
//! those 8 cycles from a concrete per-cycle schedule and proves it
//! consistent with the architecture's physical constraints:
//!
//! ```text
//! cycle 0   address generation + window shift-in (slide column fetch)
//! cycle 1   window register broadcast to the 4 PCOREs
//! cycle 2   9 parallel multipliers fire in every PCORE
//! cycle 3-6 adder tree, 4 levels (9 -> 5 -> 3 -> 2 -> 1)
//! cycle 7   accumulate into the output BMGs (read-modify-write)
//! ```
//!
//! Along the way it checks the §4.1 claim that the BMG split makes all
//! concurrent accesses conflict-free: a dual-port BMG may serve at most
//! 2 accesses per cycle, and the stepped run records every port touch
//! per cycle and asserts the bound. The adder tree is evaluated as a
//! real binary reduction (per-level wrapping in Wrap8 mode), which
//! also validates the 4-level depth the resource/timing model charges.

use super::bram::{ImageBrams, OutputBrams, WeightBrams};
use super::compute_core::PsumWord;
use super::AccumMode;
use crate::paper::{CYCLES_PER_PSUM_GROUP, KH, KW, N_PCORES};
use std::collections::HashMap;

/// What happens in each cycle of the 8-cycle window schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPhase {
    /// Address generation + image-window fetch/shift.
    Fetch,
    /// Window register broadcast.
    Broadcast,
    /// 9 parallel multipliers per PCORE.
    Multiply,
    /// Adder tree level `n` (1..=4).
    TreeLevel(u8),
    /// Output-BRAM read-modify-write accumulate.
    Accumulate,
}

/// The canonical 8-cycle schedule.
pub const SCHEDULE: [StepPhase; CYCLES_PER_PSUM_GROUP as usize] = [
    StepPhase::Fetch,
    StepPhase::Broadcast,
    StepPhase::Multiply,
    StepPhase::TreeLevel(1),
    StepPhase::TreeLevel(2),
    StepPhase::TreeLevel(3),
    StepPhase::TreeLevel(4),
    StepPhase::Accumulate,
];

/// Port-pressure record: (bank name, cycle) -> accesses that cycle.
#[derive(Debug, Default)]
pub struct PortLog {
    pub touches: HashMap<(String, u64), u32>,
    pub violations: Vec<(String, u64, u32)>,
}

impl PortLog {
    fn touch(&mut self, bank: &str, cycle: u64, n: u32) {
        let e = self.touches.entry((bank.to_string(), cycle)).or_insert(0);
        *e += n;
        if *e > 2 {
            self.violations.push((bank.to_string(), cycle, *e));
        }
    }

    pub fn max_pressure(&self) -> u32 {
        self.touches.values().copied().max().unwrap_or(0)
    }
}

/// Reduce 9 values through an explicit 4-level binary adder tree.
/// In Wrap8 mode every level wraps at 8 bits, as 8-bit adders would.
fn adder_tree(products: &[i64; 9], mode: AccumMode) -> i64 {
    let clip = |v: i64| match mode {
        AccumMode::Wrap8 => v & 0xFF,
        AccumMode::I32 => v,
    };
    let mut level: Vec<i64> = products.iter().map(|&p| clip(p)).collect();
    let mut depth = 0;
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            next.push(clip(pair.iter().sum()));
        }
        level = next;
        depth += 1;
    }
    assert_eq!(depth, 4, "9-input tree must be 4 levels deep");
    level[0]
}

/// Result of one stepped sweep.
#[derive(Debug)]
pub struct SteppedRun {
    pub cycles: u64,
    pub windows: u64,
    pub ports: PortLog,
    /// Phase executed at every cycle (for schedule assertions).
    pub phase_trace: Vec<StepPhase>,
}

/// Run one (kernel group, channel) sweep cycle-by-cycle, accumulating
/// into `out`. Semantically identical to `ComputeCore::sweep`; the
/// point is the per-cycle derivation, not speed.
pub fn sweep_stepped<T: PsumWord>(
    img: &mut ImageBrams,
    wgt: &mut WeightBrams,
    out: &mut OutputBrams<T>,
    group: usize,
    ch: usize,
) -> SteppedRun {
    let (_, h, w) = img.dims();
    let (oh, ow) = (h - KH + 1, w - KW + 1);
    let mut ports = PortLog::default();
    let mut phase_trace = Vec::new();
    let mut cycle = 0u64;

    // Weight staging (pipelined away in steady state; charged to the
    // stage-1 budget, not the 8-cycle schedule). The four kernel BMGs
    // stream in parallel: 9 values each over ceil(9/2) cycles.
    let mut weights = [[0u8; 9]; N_PCORES];
    for (j, wj) in weights.iter_mut().enumerate() {
        *wj = wgt.read_kernel_channel(N_PCORES * group + j, ch);
    }
    for c in 0..9u64.div_ceil(2) {
        for j in 0..N_PCORES {
            ports.touch(&format!("wgt_bmg_q{ch}_{j}"), cycle + c, 2);
        }
    }
    cycle += 9u64.div_ceil(2);

    let mut window = [0u8; 9];
    for y in 0..oh {
        for x in 0..ow {
            let fresh = x == 0;
            for (ci, phase) in SCHEDULE.iter().enumerate() {
                phase_trace.push(*phase);
                let c = cycle + ci as u64;
                match phase {
                    StepPhase::Fetch => {
                        if fresh {
                            // Full 9-value fetch: spread over the fetch +
                            // broadcast slots of the *previous* window in
                            // real silicon; the port log charges it here
                            // conservatively at 2/cycle over 5 cycles
                            // starting early (pipelined), so pressure
                            // still bounds at 2.
                            for (i, wv) in window.iter_mut().enumerate() {
                                let (dy, dx) = (i / 3, i % 3);
                                *wv = img.read(ch, y + dy, x + dx);
                            }
                            for cc in 0..5u64 {
                                ports.touch(&format!("img_bmg_q{ch}"), c.wrapping_sub(cc), 2);
                            }
                        } else {
                            // Slide: 3 new values, 2 ports -> 2 cycles
                            // (one overlaps broadcast).
                            for r in 0..3 {
                                window[r * 3] = window[r * 3 + 1];
                                window[r * 3 + 1] = window[r * 3 + 2];
                                window[r * 3 + 2] = img.read(ch, y + r, x + 2);
                            }
                            ports.touch(&format!("img_bmg_q{ch}"), c, 2);
                            ports.touch(&format!("img_bmg_q{ch}"), c + 1, 1);
                        }
                    }
                    StepPhase::Broadcast => { /* register transfer, no ports */ }
                    StepPhase::Multiply | StepPhase::TreeLevel(_) => { /* datapath */ }
                    StepPhase::Accumulate => {
                        for j in 0..N_PCORES {
                            let products: [i64; 9] = std::array::from_fn(|i| {
                                window[i] as i64 * weights[j][i] as i64
                            });
                            let psum = adder_tree(&products, T::MODE);
                            let k = N_PCORES * group + j;
                            let word = match T::MODE {
                                AccumMode::Wrap8 => T::from_psum(
                                    super::pcore::Psum::Wrap8((psum & 0xFF) as u8),
                                ),
                                AccumMode::I32 => {
                                    T::from_psum(super::pcore::Psum::I32(psum as i32))
                                }
                            };
                            out.accumulate(k, y, x, word);
                            // RMW = 1 read + 1 write on the kernel's bank.
                            ports.touch(&format!("out_bmg{}", k % N_PCORES), c, 2);
                        }
                    }
                }
            }
            cycle += CYCLES_PER_PSUM_GROUP;
        }
    }

    SteppedRun {
        cycles: cycle,
        windows: (oh * ow) as u64,
        ports,
        phase_trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::compute_core::ComputeCore;
    use crate::model::{golden, Tensor};
    use crate::util::prng::Prng;

    fn setup(
        c: usize,
        h: usize,
        w: usize,
        k: usize,
        seed: u64,
    ) -> (Tensor<u8>, Tensor<u8>, ImageBrams, WeightBrams) {
        let mut rng = Prng::new(seed);
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let wts = Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256));
        let mut ib = ImageBrams::new(c, h, w);
        ib.load_image(&img);
        let mut wb = WeightBrams::new(k, c);
        wb.load_weights(&wts);
        (img, wts, ib, wb)
    }

    #[test]
    fn stepped_matches_functional_model() {
        let (_, _, mut ib, mut wb) = setup(1, 6, 7, 4, 31);
        let (_, _, mut ib2, mut wb2) = setup(1, 6, 7, 4, 31);
        let mut out_stepped = OutputBrams::<i32>::new(4, 4, 5);
        out_stepped.preload_bias(&[3, 1, 4, 1]);
        let mut out_fast = OutputBrams::<i32>::new(4, 4, 5);
        out_fast.preload_bias(&[3, 1, 4, 1]);

        sweep_stepped(&mut ib, &mut wb, &mut out_stepped, 0, 0);
        let mut core = ComputeCore::new(0);
        core.sweep(&mut ib2, &mut wb2, &mut out_fast, 0, 0, None);
        assert_eq!(out_stepped.readout().data(), out_fast.readout().data());
    }

    #[test]
    fn stepped_matches_golden_both_modes() {
        let (img, wts, mut ib, mut wb) = setup(1, 5, 5, 4, 32);
        // i32
        let mut out = OutputBrams::<i32>::new(4, 3, 3);
        out.preload_bias(&[0; 4]);
        sweep_stepped(&mut ib, &mut wb, &mut out, 0, 0);
        let want = golden::conv3x3_i32(&img, &wts, &[0; 4], false);
        assert_eq!(out.readout().data(), want.data());
        // wrap8 (per-level wrapping tree must equal sequential wrap MAC)
        let (img8, wts8, mut ib8, mut wb8) = setup(1, 5, 5, 4, 32);
        let mut out8 = OutputBrams::<u8>::new(4, 3, 3);
        out8.preload_bias(&[0; 4]);
        sweep_stepped(&mut ib8, &mut wb8, &mut out8, 0, 0);
        let want8 = golden::conv3x3_wrap8(&img8, &wts8, &[0; 4]);
        assert_eq!(out8.readout().data(), want8.data());
    }

    #[test]
    fn schedule_is_eight_cycles_per_window() {
        let (_, _, mut ib, mut wb) = setup(1, 5, 5, 4, 33);
        let mut out = OutputBrams::<i32>::new(4, 3, 3);
        out.preload_bias(&[0; 4]);
        let run = sweep_stepped(&mut ib, &mut wb, &mut out, 0, 0);
        assert_eq!(run.windows, 9);
        // weight staging (5) + 9 windows x 8.
        assert_eq!(run.cycles, 5 + 9 * 8);
        assert_eq!(run.phase_trace.len(), 9 * 8);
        // Every window executes the canonical schedule in order.
        for chunk in run.phase_trace.chunks(8) {
            assert_eq!(chunk, &SCHEDULE[..]);
        }
    }

    #[test]
    fn dual_port_constraint_never_violated() {
        let (_, _, mut ib, mut wb) = setup(2, 8, 9, 8, 34);
        let mut out = OutputBrams::<i32>::new(8, 6, 7);
        out.preload_bias(&[0; 8]);
        for g in 0..2 {
            for ch in 0..2 {
                let run = sweep_stepped(&mut ib, &mut wb, &mut out, g, ch);
                assert!(
                    run.ports.violations.is_empty(),
                    "port violations: {:?}",
                    &run.ports.violations[..run.ports.violations.len().min(5)]
                );
                assert!(run.ports.max_pressure() <= 2);
            }
        }
    }

    #[test]
    fn adder_tree_is_four_levels_and_exact() {
        let products: [i64; 9] = [1, 2, 3, 4, 5, 6, 7, 8, 9];
        assert_eq!(adder_tree(&products, AccumMode::I32), 45);
        // Wrapping tree == wrapping sequential sum (mod-256 associativity).
        let big: [i64; 9] = [200, 250, 100, 90, 80, 70, 255, 255, 1];
        let seq = big.iter().fold(0i64, |a, b| (a + b) & 0xFF);
        assert_eq!(adder_tree(&big, AccumMode::Wrap8), seq);
    }
}
