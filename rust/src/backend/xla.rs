//! [`ConvBackend`] over the AOT-compiled Pallas/HLO artifacts executed
//! through PJRT ([`crate::runtime::XlaRuntime`]).
//!
//! Availability is doubly gated: the crate must be built with the
//! `xla` feature (otherwise `XlaRuntime` is the stub that fails at
//! construction) and the artifact registry must exist on disk. Both
//! failures surface in [`XlaBackend::try_new`], so pools and tests can
//! degrade by skipping this backend.
//!
//! Serving restrictions, encoded in the capability mask and re-checked
//! at run time: standard 3×3 only (the artifact set has no depthwise or
//! centre-tapped pointwise variants), raw-accumulator specs only (the
//! fused relu/pool variants transform the output, which would break the
//! backend parity contract), and only specs present in the registry.

use super::{BackendRun, Capability, ConvBackend, CostModel, JobKind, JobPayload};
use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::LayerSpec;
use crate::runtime::XlaRuntime;

/// PJRT-executed conv backend.
pub struct XlaBackend {
    rt: XlaRuntime,
}

impl XlaBackend {
    /// Build over the default artifact registry; `Err` when the `xla`
    /// feature is not linked or no artifacts are built.
    pub fn try_new() -> anyhow::Result<Self> {
        Ok(XlaBackend {
            rt: XlaRuntime::with_default_registry()?,
        })
    }

    pub fn with_runtime(rt: XlaRuntime) -> Self {
        XlaBackend { rt }
    }

    /// Raw-conv specs this backend can serve (registry ∩ contract).
    pub fn served_specs(&self) -> Vec<LayerSpec> {
        self.rt
            .registry
            .served_specs()
            .into_iter()
            .filter(|s| !s.relu && !s.pool)
            .collect()
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }
}

impl ConvBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn capability(&self) -> Capability {
        Capability {
            standard3x3: true,
            depthwise: false,
            pointwise_as_3x3: false,
            accum: AccumMode::I32,
            paper_specs_only: false,
            // The mask must agree with run(): only raw-conv specs the
            // artifact registry actually compiled. Anything else would
            // route here, fail run()'s ensures, and fail the job.
            spec_allowlist: Some(self.served_specs()),
        }
    }

    fn cost_model(&self) -> CostModel {
        // ~1 unit per PSUM: costlier than a dedicated IP core
        // (SimCycles ≈ psums/2) so accelerators fill first, far cheaper
        // than naive host loops (HostMacs = 9 × psums).
        CostModel::Vectorized {
            throughput_factor: 1,
        }
    }

    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
        anyhow::ensure!(
            job.kind == JobKind::Standard,
            "xla backend serves standard 3x3 jobs only, got {:?}",
            job.kind
        );
        anyhow::ensure!(
            !job.spec.relu && !job.spec.pool,
            "xla backend serves raw-accumulator specs only (artifact {} fuses relu/pool)",
            job.spec.name()
        );
        let cost = self.cost(job.spec, job.kind);
        let out = self.rt.run_layer(job.spec, job.img, job.weights, job.bias)?;
        // The artifacts carry exact integers in f32 (DESIGN.md §5);
        // widen back to the i32 parity format.
        Ok(BackendRun {
            output: out.map(|v| v as i32),
            cycles: CycleStats {
                compute: cost,
                total: cost,
                ..Default::default()
            },
            wire: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{golden, Tensor, QUICKSTART};
    use crate::util::prng::Prng;

    #[test]
    fn unavailable_runtime_degrades_to_err() {
        // Whichever gate is closed (feature or artifacts), try_new must
        // either produce a working backend or a skippable error.
        match XlaBackend::try_new() {
            Err(e) => {
                let msg = e.to_string();
                assert!(!msg.is_empty(), "skip reason must be reportable");
            }
            Ok(mut be) => {
                let spec = QUICKSTART;
                let mut rng = Prng::new(71);
                let img = Tensor::from_vec(
                    &[spec.c, spec.h, spec.w],
                    rng.bytes_below(spec.c * spec.h * spec.w, 128),
                );
                let wts = Tensor::from_vec(
                    &[spec.k, spec.c, 3, 3],
                    rng.bytes_below(spec.k * spec.c * 9, 32),
                );
                let bias: Vec<i32> =
                    (0..spec.k).map(|_| rng.range_i64(-20, 20) as i32).collect();
                let run = be
                    .run(&JobPayload {
                        kind: JobKind::Standard,
                        spec: &spec,
                        img: &img,
                        weights: &wts,
                        bias: &bias,
                        weights_resident: false,
                        trace_id: 0,
                    })
                    .unwrap();
                let want = golden::conv3x3_i32(&img, &wts, &bias, false);
                assert_eq!(run.output.data(), want.data());
            }
        }
    }

    #[test]
    fn capability_is_standard_only_and_allowlisted() {
        // Static shape of the mask; no runtime needed. A constructed
        // backend's mask is registry-derived (see capability()).
        let cap = Capability {
            standard3x3: true,
            depthwise: false,
            pointwise_as_3x3: false,
            accum: AccumMode::I32,
            paper_specs_only: false,
            spec_allowlist: Some(vec![QUICKSTART]),
        };
        assert!(cap.supports(JobKind::Standard));
        assert!(!cap.supports(JobKind::Depthwise));
        assert!(!cap.supports(JobKind::PointwiseAs3x3));
        assert!(cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::I32));
        assert!(!cap.allows(&crate::model::S52, JobKind::Standard, AccumMode::I32));
        assert!(!cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::Wrap8));
    }

    #[test]
    fn cost_sits_between_sim_and_host() {
        // Routing intent: accelerators fill first, naive host loops
        // last, the vectorised XLA path in between.
        let sim = CostModel::SimCycles.cost(&QUICKSTART, JobKind::Standard);
        let xla = CostModel::Vectorized { throughput_factor: 1 }.cost(&QUICKSTART, JobKind::Standard);
        let host = CostModel::HostMacs.cost(&QUICKSTART, JobKind::Standard);
        assert!(sim < xla, "sim {sim} < xla {xla}");
        assert!(xla < host, "xla {xla} < host {host}");
    }
}
