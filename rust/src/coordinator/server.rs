//! Closed-loop trace server: the front door the benches and the
//! end-to-end example drive. Submissions flow request → batcher →
//! backend pool → reply channel; the server owns the batcher and
//! collects a report (latency quantiles, simulated GOPS, batching
//! efficiency, per-backend job mix).
//!
//! The pool is built from [`CoordinatorConfig`] by [`build_pool`]:
//! `n_cores` simulated IP cores, plus `golden_fallback_workers` naive
//! host workers, plus `im2col_workers` threaded im2col+GEMM workers,
//! plus one `RemoteBackend` per `remote_peers` entry (whole TCP-served
//! machines, wire protocol v4: binary tensor frames and the
//! content-addressed weight cache negotiated per peer, batches
//! pipelined through a bounded in-flight window) — the heterogeneous
//! deployment. Depthwise trace entries exercise the capability mask:
//! they only ever route to depthwise-capable workers. Jobs a backend
//! fails (a dropped peer) come back as error results, counted in
//! [`Report::n_errors`].
//!
//! Two front doors share one paced submission core: [`Server::run_trace`]
//! (synthetic per-entry weights — every job a cache miss by design) and
//! [`Server::run_registry_trace`] (multi-tenant `(model, layer, input)`
//! submissions resolved through a [`ModelRegistry`] — same weight bytes
//! per layer on every request, which is what makes the wire-v4 weight
//! cache pay off; [`Report::n_weight_hits`] shows it).

use super::batcher::Batcher;
use super::config::CoordinatorConfig;
use super::dispatch::CorePool;
use super::request::{ConvJob, ConvResult, Submission};
use super::stream::{StreamOutcome, StreamScheduler};
use crate::backend::{
    ConvBackend, GoldenBackend, Im2colBackend, JobKind, RemoteBackend, SimBackend,
};
use crate::model::trace::TraceEntry;
use crate::registry::ModelRegistry;
use crate::util::json::Json;
use crate::util::prng::Prng;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

/// Build the worker pool a config describes: `n_cores` simulated IP
/// cores, then golden / im2col host workers, then one
/// [`RemoteBackend`] per remote peer — dialled now, so an unreachable
/// peer is a construction error rather than a silently smaller pool.
pub fn build_pool(config: &CoordinatorConfig) -> anyhow::Result<CorePool> {
    // Misconfiguration is a construction error, not a runtime panic or
    // a silently wedged deployment.
    if let Some(0) = config.max_inflight_psums {
        anyhow::bail!(
            "max_inflight_psums = 0 admits no concurrent work; \
             use None for an unbounded pool or a positive budget"
        );
    }
    let mut backends: Vec<Box<dyn ConvBackend>> = Vec::new();
    for _ in 0..config.n_cores {
        backends.push(Box::new(SimBackend::new(config.ip)));
    }
    for _ in 0..config.golden_fallback_workers {
        backends.push(Box::new(GoldenBackend::new()));
    }
    for _ in 0..config.im2col_workers {
        backends.push(Box::new(Im2colBackend::new(config.im2col_worker_threads)));
    }
    for peer in &config.remote_peers {
        backends.push(Box::new(RemoteBackend::connect(peer)?));
    }
    anyhow::ensure!(
        !backends.is_empty(),
        "config describes an empty pool (no cores, workers or peers)"
    );
    Ok(CorePool::with_backends_traced(
        backends,
        config.ip,
        config.trace.clone(),
    ))
}

/// Serving report for one trace run.
#[derive(Clone, Debug)]
pub struct Report {
    pub n_requests: usize,
    pub n_cores: usize,
    pub wall: Duration,
    /// Simulated hardware time (max over cores would need per-core
    /// tracking; we report aggregate cycles / n_cores as the even-load
    /// estimate, which trace tests validate). Host-fallback workers
    /// contribute modelled-equivalent cycles (their cost model).
    pub sim_gops_psum: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Tail-of-the-tail request latency (99.9th percentile), linearly
    /// interpolated inside the winning histogram bucket — meaningful
    /// even when a run's worst requests all land in one power-of-two
    /// bucket.
    pub p999_us: u64,
    pub total_psums: u64,
    pub weight_dma_skip_rate: f64,
    /// Wire-v4 weight-cache hits across the pool's remote workers:
    /// submissions whose weight blob stayed off the wire because the
    /// peer's content-addressed store already held it.
    pub n_weight_hits: u64,
    /// Wire-v4 weight-cache misses: blobs shipped inline (cold peer,
    /// store eviction, or a redial that dropped residency beliefs).
    pub n_weight_misses: u64,
    /// Weight bytes that never crossed the wire thanks to cache hits.
    pub wire_weight_bytes_saved: u64,
    /// Host-side throughput (requests/s) — the simulator's own speed.
    pub host_rps: f64,
    /// Jobs answered with an error result (e.g. a dropped remote peer)
    /// — answered, never lost, but carrying no numerics.
    pub n_errors: usize,
    /// Requests refused up front by admission control (fast rejection,
    /// never queued; not counted in `n_requests`' answered results).
    pub n_shed: usize,
    /// Failover hops: jobs a worker failed that the pool re-enqueued on
    /// a capable sibling (one job can contribute several hops).
    pub n_retried: usize,
    /// Unhealthy→healthy transitions observed across the pool's
    /// health-tracked (remote) workers — peers that came back.
    pub n_recovered_peers: u64,
    /// Answered jobs per backend name (heterogeneous-pool routing;
    /// remote workers appear as `remote@host:port`).
    pub backend_mix: Vec<(&'static str, usize)>,
    /// Whole-network streaming front only ([`Server::run_stream_trace`]):
    /// images whose full layer chain was served. Zero on the per-layer
    /// trace fronts.
    pub n_images: usize,
    /// Streaming throughput: completed images / wall. Zero on the
    /// per-layer trace fronts.
    pub images_per_sec: f64,
}

/// The server: config + backend pool.
pub struct Server {
    pub config: CoordinatorConfig,
    pool: CorePool,
}

impl Server {
    /// Build the pool the config describes; panics when a remote peer
    /// is unreachable (use [`Self::try_new`] to handle that).
    pub fn new(config: CoordinatorConfig) -> Self {
        Self::try_new(config).expect("coordinator pool construction")
    }

    pub fn try_new(config: CoordinatorConfig) -> anyhow::Result<Self> {
        let pool = build_pool(&config)?;
        // A configured scrape endpoint goes live against this pool the
        // moment the server exists — mid-run scrapes see live counters.
        if let Some(scrape) = &config.scrape {
            scrape.attach(pool.scrape_source());
        }
        Ok(Server { config, pool })
    }

    /// Per-stage latency histogram observation counts (stage name →
    /// samples recorded) — the CLI smoke legs assert on these without
    /// reaching into the pool.
    pub fn stage_counts(&self) -> Vec<(String, u64)> {
        self.pool
            .metrics
            .stages
            .labelled()
            .into_iter()
            .map(|(name, h)| (name, h.count()))
            .collect()
    }

    /// The pool's span sink, when the config enabled tracing — the CLI
    /// exports [`crate::telemetry::SpanSink::to_chrome_trace`] from it
    /// after a run.
    pub fn span_sink(&self) -> Option<std::sync::Arc<crate::telemetry::SpanSink>> {
        self.pool.span_sink()
    }

    /// Run a whole trace closed-loop (submit all, await all). When
    /// `max_inflight_psums` is set, submission blocks on backpressure
    /// while a collector thread drains completions.
    pub fn run_trace(&mut self, trace: &[TraceEntry]) -> Report {
        self.run_trace_with(trace, &mut |_| {})
    }

    /// Like [`Self::run_trace`], but paces submission by each entry's
    /// `arrival_us` (so the trace is an open-loop arrival process, not
    /// an instantaneous burst) and calls `on_entry(i)` just before
    /// submitting entry `i` — the chaos harness's hook for killing and
    /// reviving peers mid-trace. Blocked admission waits are bounded by
    /// a backstop deadline: a wedged pool sheds instead of hanging the
    /// run, and shed entries are reported in [`Report::n_shed`] rather
    /// than answered.
    pub fn run_trace_with(
        &mut self,
        trace: &[TraceEntry],
        on_entry: &mut dyn FnMut(usize),
    ) -> Report {
        self.run_paced(
            trace.len(),
            &mut |i| trace[i].arrival_us,
            &mut |i| match trace[i].kind {
                JobKind::Depthwise => {
                    ConvJob::synthetic_depthwise(i as u64, trace[i].spec, trace[i].seed)
                }
                _ => ConvJob::synthetic(i as u64, trace[i].spec, trace[i].seed),
            },
            on_entry,
        )
    }

    /// Run a multi-tenant registry trace: `n` paced submissions, each
    /// resolved as `(model, layer)` by [`ModelRegistry::pick`] and built
    /// from the manifest's weights ([`ModelRegistry::job`]) with a
    /// per-request deterministic input image. Because every request for
    /// a layer reuses the *same* weight bytes, remote wire-v4 peers see
    /// each blob at most once per peer lifetime —
    /// [`Report::n_weight_hits`] counts the submissions that rode the
    /// cache. Arrival pacing mirrors `model::trace::generate`: uniform
    /// gaps in `[0, 2*mean_gap_us]`, integer-deterministic from `seed`.
    pub fn run_registry_trace(
        &mut self,
        registry: &ModelRegistry,
        n: usize,
        mean_gap_us: u64,
        seed: u64,
    ) -> Report {
        let mut rng = Prng::new(seed);
        let mut t = 0u64;
        let arrivals: Vec<u64> = (0..n)
            .map(|_| {
                if mean_gap_us > 0 {
                    t += rng.below(2 * mean_gap_us + 1);
                }
                t
            })
            .collect();
        self.run_paced(
            n,
            &mut |i| arrivals[i],
            &mut |i| {
                let (model, layer) = registry.pick(i as u64, seed);
                registry
                    .job(model, layer, i as u64, seed ^ ((i as u64) << 1))
                    .expect("pick() only yields in-range (model, layer) pairs")
            },
            &mut |_| {},
        )
    }

    /// The shared paced-submission core both trace fronts drive:
    /// `make_job(i)` builds submission `i`, `arrival_us(i)` paces it
    /// (absolute µs from run start), `on_entry(i)` fires just before
    /// submission — the chaos harness's hook for killing and reviving
    /// peers mid-trace. Blocked admission waits are bounded by a
    /// backstop deadline: a wedged pool sheds instead of hanging the
    /// run, and shed entries are reported in [`Report::n_shed`] rather
    /// than answered.
    fn run_paced(
        &mut self,
        n: usize,
        arrival_us: &mut dyn FnMut(usize) -> u64,
        make_job: &mut dyn FnMut(usize) -> ConvJob,
        on_entry: &mut dyn FnMut(usize),
    ) -> Report {
        use super::backpressure::{Admission, AdmissionController, Policy};
        use std::sync::Arc;

        /// How long a Block-policy submitter waits for the pool to
        /// drain before shedding the entry. Generous enough that only a
        /// genuinely wedged pool ever trips it.
        const ADMIT_BACKSTOP: Duration = Duration::from_secs(60);

        let mut batcher = Batcher::new(self.config.batch);
        let (tx, rx) = channel::<ConvResult>();
        let start = Instant::now();
        let mut n_shed = 0usize;

        let admission = self
            .config
            .max_inflight_psums
            .map(|cap| Arc::new(AdmissionController::new(cap)));
        // Collector drains results (and releases admission budget) while
        // the main thread keeps submitting — mandatory under Block policy.
        let collector = {
            let admission = admission.clone();
            std::thread::spawn(move || {
                let mut results = Vec::new();
                while let Ok(r) = rx.recv() {
                    if let Some(ac) = &admission {
                        ac.complete(r.psums());
                    }
                    results.push(r);
                }
                results
            })
        };

        let tracing = self.config.trace.is_some();
        for i in 0..n {
            on_entry(i);
            // Open-loop pacing: wait out the gap to this entry's
            // arrival time (arrival_us is absolute from trace start; a
            // mean_gap_us=0 trace degenerates to the old burst).
            let due = Duration::from_micros(arrival_us(i));
            if let Some(wait) = due.checked_sub(start.elapsed()) {
                if !wait.is_zero() {
                    std::thread::sleep(wait);
                }
            }
            // Admission wait is measured from here: everything until
            // the submission is enqueued is time the request spent at
            // the front door (zero on an unbounded pool).
            let admit_start = Instant::now();
            let mut job = make_job(i);
            if let Some(ac) = &admission {
                // Admitted-but-unbatched work can't complete; flush open
                // batches before blocking or the budget never frees.
                if ac.admit(job.psums(), Policy::Reject) == Admission::Rejected {
                    for open in batcher.flush() {
                        self.pool.dispatch(open);
                    }
                    if ac.admit_deadline(job.psums(), ADMIT_BACKSTOP) == Admission::Rejected {
                        // Wedged (or shutting-down) pool: shed rather
                        // than hang the submitter forever.
                        self.pool.metrics.record_shed();
                        n_shed += 1;
                        continue;
                    }
                }
            }
            let admission_us = admit_start.elapsed().as_micros() as u64;
            self.pool.metrics.stages.admission.record_us(admission_us);
            if tracing {
                // Trace ids are minted at the front door: sequential,
                // nonzero (0 is the "untraced" sentinel everywhere).
                job.trace.id = i as u64 + 1;
                job.trace.admission_us = admission_us;
            }
            let sub = Submission {
                job,
                reply: tx.clone(),
                enqueued: Instant::now(),
            };
            for closed in batcher.push(sub) {
                self.pool.dispatch(closed);
            }
        }
        for leftover in batcher.flush() {
            self.pool.dispatch(leftover);
        }
        drop(tx);

        let results = collector.join().expect("collector thread");
        let wall = start.elapsed();
        assert_eq!(
            results.len(),
            n - n_shed,
            "every admitted request answered"
        );

        let mut mix: BTreeMap<&'static str, usize> = BTreeMap::new();
        let mut n_errors = 0usize;
        for r in &results {
            *mix.entry(r.backend).or_default() += 1;
            if r.error.is_some() {
                n_errors += 1;
            }
        }

        let m = &self.pool.metrics;
        let completed = m.completed.load(Ordering::Relaxed);
        let skipped = m.weight_dma_skipped.load(Ordering::Relaxed);
        let (weight_hits, weight_misses, weight_bytes_saved) = self.pool.weight_cache_stats();
        Report {
            n_requests: results.len(),
            n_cores: self.pool.n_cores(),
            wall,
            sim_gops_psum: m.sim_gops_psum(self.config.ip.freq_hz, self.pool.n_cores()),
            p50_us: m.stages.request.quantile_us(0.5),
            p99_us: m.stages.request.quantile_us(0.99),
            p999_us: m.stages.request.quantile_us(0.999),
            total_psums: m.psums.load(Ordering::Relaxed),
            weight_dma_skip_rate: if completed == 0 {
                0.0
            } else {
                skipped as f64 / completed as f64
            },
            n_weight_hits: weight_hits,
            n_weight_misses: weight_misses,
            wire_weight_bytes_saved: weight_bytes_saved,
            host_rps: results.len() as f64 / wall.as_secs_f64().max(1e-9),
            n_errors,
            n_shed: m.shed.load(Ordering::Relaxed) as usize,
            n_retried: m.retried.load(Ordering::Relaxed) as usize,
            n_recovered_peers: self.pool.recovered_peers(),
            backend_mix: mix.into_iter().collect(),
            n_images: 0,
            images_per_sec: 0.0,
        }
    }

    /// The whole-network streaming front door: `n_images` images, image
    /// `i` submitted against model `i % n_models`, each walked through
    /// its manifest's layer chain across the pool by a
    /// [`StreamScheduler`] with the config's in-flight-images window
    /// ([`CoordinatorConfig::stream_window`]). `on_image(i)` fires just
    /// before image `i` is admitted — the chaos hook. Returns the pool
    /// report (with [`Report::n_images`] / [`Report::images_per_sec`]
    /// populated) plus the full per-image outcome, already checked
    /// bit-exact against [`ModelRegistry`]'s own golden forward.
    pub fn run_stream_trace(
        &mut self,
        registry: &ModelRegistry,
        n_images: usize,
        seed: u64,
        on_image: &mut dyn FnMut(usize),
    ) -> (Report, StreamOutcome) {
        let outcome = StreamScheduler::new(&self.pool, registry, self.config.stream_window)
            .run_with(n_images, seed, on_image);
        let m = &self.pool.metrics;
        let completed = m.completed.load(Ordering::Relaxed);
        let skipped = m.weight_dma_skipped.load(Ordering::Relaxed);
        let (weight_hits, weight_misses, weight_bytes_saved) = self.pool.weight_cache_stats();
        let report = Report {
            n_requests: outcome.n_layer_jobs,
            n_cores: self.pool.n_cores(),
            wall: outcome.wall,
            sim_gops_psum: m.sim_gops_psum(self.config.ip.freq_hz, self.pool.n_cores()),
            p50_us: m.stages.request.quantile_us(0.5),
            p99_us: m.stages.request.quantile_us(0.99),
            p999_us: m.stages.request.quantile_us(0.999),
            total_psums: m.psums.load(Ordering::Relaxed),
            weight_dma_skip_rate: if completed == 0 {
                0.0
            } else {
                skipped as f64 / completed as f64
            },
            n_weight_hits: weight_hits,
            n_weight_misses: weight_misses,
            wire_weight_bytes_saved: weight_bytes_saved,
            host_rps: outcome.n_layer_jobs as f64 / outcome.wall.as_secs_f64().max(1e-9),
            n_errors: outcome.images.iter().filter(|o| o.error.is_some()).count(),
            n_shed: m.shed.load(Ordering::Relaxed) as usize,
            n_retried: m.retried.load(Ordering::Relaxed) as usize,
            n_recovered_peers: self.pool.recovered_peers(),
            backend_mix: outcome.backend_mix.clone(),
            n_images: outcome.images.len(),
            images_per_sec: outcome.images_per_sec(),
        };
        (report, outcome)
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

impl Report {
    pub fn render(&self) -> String {
        let mix = self
            .backend_mix
            .iter()
            .map(|(name, n)| format!("{name}x{n}"))
            .collect::<Vec<_>>()
            .join(",");
        let stream = if self.n_images > 0 {
            format!(
                "\nstream: images={} images_per_sec={:.1}",
                self.n_images, self.images_per_sec
            )
        } else {
            String::new()
        };
        format!(
            "requests={} cores={} wall={:?} host_rps={:.1} errors={} shed={} retried={} recovered_peers={}\n\
             sim_gops(psum)={:.4} total_psums={} p50={}us p99={}us p999={}us wdma_skip={:.0}% \
             wcache_hits={} wcache_misses={} wcache_saved={}B mix=[{}]{}",
            self.n_requests,
            self.n_cores,
            self.wall,
            self.host_rps,
            self.n_errors,
            self.n_shed,
            self.n_retried,
            self.n_recovered_peers,
            self.sim_gops_psum,
            self.total_psums,
            self.p50_us,
            self.p99_us,
            self.p999_us,
            self.weight_dma_skip_rate * 100.0,
            self.n_weight_hits,
            self.n_weight_misses,
            self.wire_weight_bytes_saved,
            mix,
            stream
        )
    }

    /// Machine-readable form (the `BENCH_serving.json` trajectory the
    /// CLI emits for CI and benchmarking).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_requests", Json::num(self.n_requests as f64)),
            ("n_cores", Json::num(self.n_cores as f64)),
            ("n_images", Json::num(self.n_images as f64)),
            ("images_per_sec", Json::num(self.images_per_sec)),
            ("n_errors", Json::num(self.n_errors as f64)),
            ("n_shed", Json::num(self.n_shed as f64)),
            ("n_retried", Json::num(self.n_retried as f64)),
            ("n_recovered_peers", Json::num(self.n_recovered_peers as f64)),
            ("wall_us", Json::num(self.wall.as_micros() as f64)),
            ("host_rps", Json::num(self.host_rps)),
            ("sim_gops_psum", Json::num(self.sim_gops_psum)),
            ("p50_us", Json::num(self.p50_us as f64)),
            ("p99_us", Json::num(self.p99_us as f64)),
            ("p999_us", Json::num(self.p999_us as f64)),
            ("total_psums", Json::num(self.total_psums as f64)),
            ("weight_dma_skip_rate", Json::num(self.weight_dma_skip_rate)),
            ("n_weight_hits", Json::num(self.n_weight_hits as f64)),
            ("n_weight_misses", Json::num(self.n_weight_misses as f64)),
            (
                "wire_weight_bytes_saved",
                Json::num(self.wire_weight_bytes_saved as f64),
            ),
            (
                "backend_mix",
                Json::obj(
                    self.backend_mix
                        .iter()
                        .map(|(name, n)| (*name, Json::num(*n as f64)))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::trace::{generate, total_psums, TraceConfig};

    fn small_trace(n: usize) -> Vec<TraceEntry> {
        generate(&TraceConfig {
            n,
            mean_gap_us: 0,
            s52_fraction: 0.0, // keep tests fast: edge-CNN shapes only
            depthwise_fraction: 0.0,
            seed: 3,
        })
    }

    #[test]
    fn trace_run_answers_everything() {
        let mut server = Server::new(CoordinatorConfig::default().with_cores(2));
        let trace = small_trace(16);
        let report = server.run_trace(&trace);
        assert_eq!(report.n_requests, 16);
        assert_eq!(report.total_psums, total_psums(&trace));
        assert!(report.sim_gops_psum > 0.0);
        server.shutdown();
    }

    #[test]
    fn batching_skips_weight_dma() {
        let mut server = Server::new(CoordinatorConfig::default());
        // Same-shape burst -> most jobs reuse resident weights.
        let trace: Vec<TraceEntry> = small_trace(1)
            .into_iter()
            .cycle()
            .take(12)
            .collect();
        let report = server.run_trace(&trace);
        assert!(
            report.weight_dma_skip_rate > 0.5,
            "skip rate {}",
            report.weight_dma_skip_rate
        );
        server.shutdown();
    }

    #[test]
    fn backpressure_bounded_run_completes() {
        let mut server = Server::new(CoordinatorConfig {
            // Budget ~ two small layers: forces constant blocking.
            max_inflight_psums: Some(20_000),
            ..CoordinatorConfig::default().with_cores(2)
        });
        let trace = small_trace(24);
        let report = server.run_trace(&trace);
        assert_eq!(report.n_requests, 24);
        assert_eq!(report.total_psums, total_psums(&trace));
        server.shutdown();
    }

    #[test]
    fn report_renders() {
        let mut server = Server::new(CoordinatorConfig::default());
        let report = server.run_trace(&small_trace(4));
        let text = report.render();
        assert!(text.contains("requests=4"));
        assert!(text.contains("sim-ipcore-i32"));
        server.shutdown();
    }

    #[test]
    fn heterogeneous_pool_serves_mixed_kind_trace() {
        // Acceptance scenario: sim + golden pool, trace with depthwise
        // traffic. Everything is answered, PSUM accounting is
        // kind-aware, and the mix report names both backend types when
        // fallback workers absorb load.
        let mut server = Server::new(
            CoordinatorConfig::default().with_cores(2).with_golden_workers(2),
        );
        let trace = generate(&TraceConfig {
            n: 32,
            mean_gap_us: 0,
            s52_fraction: 0.0,
            depthwise_fraction: 0.4,
            seed: 21,
        });
        assert!(
            trace.iter().any(|e| e.kind == crate::backend::JobKind::Depthwise),
            "trace must contain depthwise entries"
        );
        let report = server.run_trace(&trace);
        assert_eq!(report.n_requests, 32);
        assert_eq!(report.total_psums, total_psums(&trace));
        assert_eq!(report.n_cores, 4);
        let served: usize = report.backend_mix.iter().map(|(_, n)| n).sum();
        assert_eq!(served, 32);
        // No depthwise-incapable backend exists in this pool; routing
        // exclusion is covered in dispatch tests with a wrap8 worker.
        server.shutdown();
    }

    #[test]
    fn build_pool_rejects_zero_total_workers() {
        let cfg = CoordinatorConfig {
            n_cores: 0,
            ..CoordinatorConfig::default()
        };
        let err = build_pool(&cfg).expect_err("empty pool must not build");
        assert!(err.to_string().contains("empty pool"), "{err}");
    }

    #[test]
    fn build_pool_rejects_unreachable_remote_peer() {
        // Port 1 is essentially never bound; dialling must surface a
        // clean construction error, not a panic or a silent absence.
        let cfg = CoordinatorConfig {
            n_cores: 0,
            ..CoordinatorConfig::default().with_remote_peer("127.0.0.1:1")
        };
        assert!(build_pool(&cfg).is_err(), "dead peer must fail construction");
    }

    #[test]
    fn build_pool_rejects_zero_admission_budget() {
        let cfg = CoordinatorConfig {
            max_inflight_psums: Some(0),
            ..CoordinatorConfig::default()
        };
        let err = build_pool(&cfg).expect_err("zero budget must not build");
        assert!(err.to_string().contains("max_inflight_psums"), "{err}");
        // Same config through the server front door: clean error too.
        assert!(Server::try_new(cfg).is_err());
    }

    #[test]
    fn paced_trace_respects_arrival_times() {
        let mut server = Server::new(CoordinatorConfig::default());
        // 8 entries, ~2 ms mean gap: the run cannot finish faster than
        // the last arrival.
        let trace = generate(&TraceConfig {
            n: 8,
            mean_gap_us: 2000,
            s52_fraction: 0.0,
            depthwise_fraction: 0.0,
            seed: 9,
        });
        let last_arrival = trace.last().unwrap().arrival_us;
        assert!(last_arrival > 0);
        let mut seen = Vec::new();
        let report = server.run_trace_with(&trace, &mut |i| seen.push(i));
        assert_eq!(report.n_requests, 8);
        assert_eq!(seen, (0..8).collect::<Vec<_>>(), "hook fires per entry, in order");
        assert!(
            report.wall >= Duration::from_micros(last_arrival),
            "paced run finished before its last arrival: {:?} < {last_arrival}us",
            report.wall
        );
        server.shutdown();
    }

    #[test]
    fn report_to_json_is_machine_readable() {
        let mut server = Server::new(CoordinatorConfig::default());
        let report = server.run_trace(&small_trace(4));
        let j = report.to_json();
        assert_eq!(j.get(&["n_requests"]).unwrap().as_usize(), Some(4));
        assert_eq!(j.get(&["n_errors"]).unwrap().as_usize(), Some(0));
        assert_eq!(j.get(&["n_shed"]).unwrap().as_usize(), Some(0));
        assert_eq!(j.get(&["n_retried"]).unwrap().as_usize(), Some(0));
        assert_eq!(j.get(&["n_recovered_peers"]).unwrap().as_usize(), Some(0));
        assert!(j.get(&["host_rps"]).unwrap().as_f64().unwrap() > 0.0);
        // Local pool, synthetic weights: the weight cache never engages.
        assert_eq!(j.get(&["n_weight_hits"]).unwrap().as_usize(), Some(0));
        assert_eq!(j.get(&["n_weight_misses"]).unwrap().as_usize(), Some(0));
        assert_eq!(j.get(&["wire_weight_bytes_saved"]).unwrap().as_usize(), Some(0));
        assert_eq!(
            j.get(&["backend_mix", "sim-ipcore-i32"]).unwrap().as_usize(),
            Some(4)
        );
        // And it round-trips through the emitter/parser.
        let text = j.to_json();
        assert_eq!(Json::parse(&text).unwrap(), j);
        server.shutdown();
    }

    #[test]
    fn remote_peers_join_the_pool_and_serve_a_mixed_trace() {
        // The fleet acceptance scenario, in-library: two in-process TCP
        // peers fronted by one remote-only pool. Every request is
        // answered without error and the mix names the remote workers.
        use crate::coordinator::tcp::TcpServer;
        let peer_a = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(2),
        )
        .expect("peer a");
        let peer_b = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_im2col_workers(1),
        )
        .expect("peer b");
        let cfg = CoordinatorConfig {
            n_cores: 0,
            ..CoordinatorConfig::default().with_remote_peers(vec![
                peer_a.addr.to_string(),
                peer_b.addr.to_string(),
            ])
        };
        let mut front = Server::try_new(cfg).expect("front pool dials both peers");
        let trace = generate(&TraceConfig {
            n: 24,
            mean_gap_us: 0,
            s52_fraction: 0.0,
            depthwise_fraction: 0.3,
            seed: 41,
        });
        let report = front.run_trace(&trace);
        assert_eq!(report.n_requests, 24);
        assert_eq!(report.n_errors, 0, "{report:?}");
        assert_eq!(report.n_cores, 2, "one pool worker per peer");
        let served: usize = report.backend_mix.iter().map(|(_, n)| n).sum();
        assert_eq!(served, 24);
        assert!(
            report.backend_mix.iter().all(|(name, _)| name.starts_with("remote@")),
            "{:?}",
            report.backend_mix
        );
        front.shutdown();
        peer_a.stop();
        peer_b.stop();
    }

    #[test]
    fn registry_trace_on_a_local_pool_answers_everything() {
        // The registry front door over plain local cores: multi-tenant
        // submissions are just jobs; no remote peer means no weight
        // cache, and the report says so.
        let mut server = Server::new(CoordinatorConfig::default().with_cores(2));
        let reg = ModelRegistry::builtin(2, 11);
        let report = server.run_registry_trace(&reg, 12, 0, 7);
        assert_eq!(report.n_requests, 12);
        assert_eq!(report.n_errors, 0, "{report:?}");
        assert_eq!(report.n_weight_hits, 0);
        assert_eq!(report.n_weight_misses, 0);
        // Deterministic: the same registry trace replays identically.
        let mut server2 = Server::new(CoordinatorConfig::default().with_cores(2));
        let report2 = server2.run_registry_trace(&reg, 12, 0, 7);
        assert_eq!(report2.total_psums, report.total_psums);
        server.shutdown();
        server2.shutdown();
    }

    #[test]
    fn registry_trace_over_a_v4_peer_ships_each_blob_once() {
        // The tentpole acceptance at the serving layer: a repeated-model
        // trace through a remote v4 peer ships each distinct weight blob
        // at most once per peer lifetime; everything else is cache hits.
        use crate::coordinator::tcp::TcpServer;
        let peer = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(2),
        )
        .expect("peer");
        let cfg = CoordinatorConfig {
            n_cores: 0,
            ..CoordinatorConfig::default().with_remote_peer(peer.addr.to_string())
        };
        let mut front = Server::try_new(cfg).expect("front dials the peer");
        let reg = ModelRegistry::builtin(2, 13);
        let n = 24;
        let report = front.run_registry_trace(&reg, n, 0, 19);
        assert_eq!(report.n_requests, n);
        assert_eq!(report.n_errors, 0, "{report:?}");
        assert!(
            report.n_weight_hits > 0,
            "repeated-model traffic must ride the cache: {report:?}"
        );
        assert!(report.wire_weight_bytes_saved > 0);
        // At most one inline ship per distinct blob this trace touched.
        assert!(
            (report.n_weight_misses as usize) <= reg.distinct_weight_hashes(),
            "misses {} > distinct blobs {}",
            report.n_weight_misses,
            reg.distinct_weight_hashes()
        );
        assert_eq!(
            report.n_weight_hits + report.n_weight_misses,
            n as u64,
            "every submission is either a hit or a miss over a wcache peer"
        );
        front.shutdown();
        peer.stop();
    }

    #[test]
    fn stream_trace_on_a_local_pool_matches_golden_and_reports_rate() {
        let mut server = Server::new(
            CoordinatorConfig::default().with_cores(2).with_stream_window(3),
        );
        let reg = ModelRegistry::builtin(2, 11);
        let (report, outcome) = server.run_stream_trace(&reg, 5, 7, &mut |_| {});
        assert_eq!(report.n_images, 5);
        assert!(report.images_per_sec > 0.0);
        assert_eq!(report.n_errors, 0, "{report:?}");
        assert!(outcome.all_match(), "{:?}", outcome.images);
        assert!(outcome.overlap_events > 0, "window=3 must overlap images");
        // Layer jobs flowed through the same pool metrics as any trace.
        assert_eq!(report.n_requests, outcome.n_layer_jobs);
        // And the streaming fields survive the JSON emitter round-trip.
        let j = report.to_json();
        assert_eq!(j.get(&["n_images"]).unwrap().as_usize(), Some(5));
        assert!(j.get(&["images_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(report.render().contains("images=5"));
        server.shutdown();
    }

    #[test]
    fn stream_trace_over_a_v4_peer_rides_the_weight_store_across_images() {
        // The tentpole acceptance at the serving layer: image 0 ships
        // each layer's blob inline; every later image's layers hit the
        // peer's content-addressed store.
        use crate::coordinator::tcp::TcpServer;
        let peer = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(2),
        )
        .expect("peer");
        let cfg = CoordinatorConfig {
            n_cores: 0,
            ..CoordinatorConfig::default()
                .with_remote_peer(peer.addr.to_string())
                .with_stream_window(4)
        };
        let mut front = Server::try_new(cfg).expect("front dials the peer");
        let reg = ModelRegistry::builtin(1, 13);
        let (report, outcome) = front.run_stream_trace(&reg, 4, 19, &mut |_| {});
        assert_eq!(report.n_images, 4);
        assert!(outcome.all_match(), "{:?}", outcome.images);
        assert!(
            report.n_weight_hits > 0,
            "repeat images must ride the weight store: {report:?}"
        );
        // At most one inline ship per distinct blob in the model.
        assert!(
            (report.n_weight_misses as usize) <= reg.distinct_weight_hashes(),
            "misses {} > distinct blobs {}",
            report.n_weight_misses,
            reg.distinct_weight_hashes()
        );
        front.shutdown();
        peer.stop();
    }

    #[test]
    fn traced_run_yields_complete_span_trees_and_a_live_scrape() {
        use crate::telemetry::scrape::ScrapeServer;
        use crate::telemetry::{validate_coverage, SpanSink};
        use std::io::{Read as _, Write as _};
        use std::sync::Arc;

        let sink = Arc::new(SpanSink::new());
        let scrape = Arc::new(ScrapeServer::bind("127.0.0.1:0").unwrap());
        let mut server = Server::new(
            CoordinatorConfig::default()
                .with_cores(2)
                .with_trace(Arc::clone(&sink))
                .with_scrape(Arc::clone(&scrape)),
        );
        let report = server.run_trace(&small_trace(12));
        assert_eq!(report.n_requests, 12);
        assert!(report.p50_us <= report.p99_us && report.p99_us <= report.p999_us);

        // Every answered request left a complete span tree in the ring:
        // one Request root whose children cover its wall time.
        let check = validate_coverage(&sink.snapshot()).expect("span trees validate");
        assert_eq!(check.roots, 12, "one Request root per answered request");

        // The scrape endpoint (attached at construction) serves the
        // same run: counters, stage-keyed buckets, worker gauges.
        let mut s = std::net::TcpStream::connect(scrape.addr()).unwrap();
        write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut body = String::new();
        s.read_to_string(&mut body).unwrap();
        assert!(body.contains("repro_completed_total 12"), "{body}");
        assert!(
            body.contains("repro_stage_latency_us_count{stage=\"request\"} 12"),
            "{body}"
        );
        assert!(
            body.contains("repro_stage_latency_us_count{stage=\"admission\"} 12"),
            "{body}"
        );
        assert!(body.contains("repro_worker_load{worker=\"sim-ipcore-i32-0\"}"), "{body}");
        server.shutdown();
        scrape.stop();
    }

    #[test]
    fn im2col_workers_join_the_pool_and_serve_mixed_traffic() {
        let mut server = Server::new(
            CoordinatorConfig::default()
                .with_cores(1)
                .with_im2col_workers(2)
                .with_im2col_worker_threads(2),
        );
        let trace = generate(&TraceConfig {
            n: 24,
            mean_gap_us: 0,
            s52_fraction: 0.0,
            depthwise_fraction: 0.3,
            seed: 31,
        });
        let report = server.run_trace(&trace);
        assert_eq!(report.n_requests, 24);
        assert_eq!(report.n_cores, 3);
        assert_eq!(report.total_psums, total_psums(&trace));
        let served: usize = report.backend_mix.iter().map(|(_, n)| n).sum();
        assert_eq!(served, 24);
        server.shutdown();
    }
}
