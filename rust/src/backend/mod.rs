//! The execution-backend seam: every way this system can compute a
//! convolution layer sits behind one [`ConvBackend`] trait.
//!
//! The paper ships a single fixed-function IP core; a deployment mixes
//! compute substrates — replicated accelerator cores, host-CPU
//! fallback, a compiled XLA path — and routes each layer job to a
//! capable, least-loaded unit (the pattern the FPGA-CNN survey
//! literature calls heterogeneous per-layer scheduling). This module
//! is that seam:
//!
//! * [`ConvBackend`] — executes one conv-layer job ([`JobPayload`]) and
//!   reports its output plus a simulated/modelled cost ([`BackendRun`]);
//! * [`Capability`] — what the backend can run: standard 3×3,
//!   depthwise, pointwise-as-3×3, and which accumulator mode it
//!   produces;
//! * [`CostModel`] — a cheap, `Copy` cost estimator the dispatcher uses
//!   for capability-masked, cost-weighted least-loaded routing without
//!   reaching into worker threads;
//! * [`sim::SimBackend`] — the cycle-accurate [`crate::hw::IpCore`]
//!   (standard, pointwise-as-3×3, and depthwise through the same entry
//!   point);
//! * [`golden::GoldenBackend`] — the naive CPU reference, kept as the
//!   anchor every other path is measured against;
//! * [`im2col::Im2colBackend`] — the serious host fallback: threaded
//!   im2col + cache-blocked GEMM (`model::im2col`), the canonical
//!   CPU formulation in the FPGA-CNN survey literature;
//! * [`xla::XlaBackend`] — the AOT Pallas/HLO artifacts under PJRT
//!   (available when the `xla` feature is linked and artifacts exist);
//! * [`remote::RemoteBackend`] — a whole remote machine behind the
//!   TCP wire protocol ([`crate::coordinator::tcp`]): the peer's
//!   `hello` handshake advertises its capability, and the pool treats
//!   it as one more capability-masked worker. Batches pipeline across
//!   the socket ([`ConvBackend::run_batch`]) with tensors in binary
//!   frames (v3), and against a `wcache` peer (v4) weight blobs ship
//!   by content hash — at most once per peer lifetime
//!   ([`KnownWeights`]), re-sent inline only on a `need_weights` miss.
//!
//! The parity contract: for identical integer inputs every backend
//! produces bit-identical i32 outputs (`rust/tests/backend_parity.rs`).
//!
//! Routing is masked four ways: job *kind* against the capability
//! flags, job *accumulator requirement* against [`Capability::accum`]
//! (a wrap-8 reply can only come from a wrap-8 core, and vice versa),
//! the spec against the §4.1 gate ([`Capability::paper_specs_only`] —
//! the IP core and remote peers reject `K % 4 != 0`), and the spec
//! against any backend allowlist.

pub mod golden;
pub mod im2col;
pub mod remote;
pub mod sim;
pub mod xla;

pub use golden::GoldenBackend;
pub use im2col::Im2colBackend;
pub use remote::RemoteBackend;
pub use sim::SimBackend;
pub use xla::XlaBackend;

use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::{LayerSpec, Tensor};
use crate::paper::{CYCLES_PER_PSUM_GROUP, N_CORES, N_PCORES};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared liveness flag for a backend whose availability can change at
/// runtime (today: [`remote::RemoteBackend`], whose probe thread flips
/// it as the peer comes and goes). The dispatcher reads it on every
/// routing decision: an unhealthy worker is masked out *preferentially*
/// — if healthy capable workers exist they absorb the traffic, but a
/// pool whose only capable workers are all unhealthy still routes to
/// them (degraded capacity must never become lost correctness; the
/// failover retry path covers the jobs that then fail).
#[derive(Debug)]
pub struct WorkerHealth {
    healthy: AtomicBool,
    /// Unhealthy→healthy transitions observed (a revived peer counts
    /// once per outage it comes back from). Flows into
    /// `Report::n_recovered_peers`.
    recoveries: AtomicU64,
}

impl WorkerHealth {
    pub fn new() -> Arc<Self> {
        Arc::new(WorkerHealth {
            healthy: AtomicBool::new(true),
            recoveries: AtomicU64::new(0),
        })
    }

    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    /// Set the flag, counting false→true edges as recoveries. Multiple
    /// observers (probe thread, the job path itself) may call this
    /// concurrently; `swap` makes each edge count exactly once.
    pub fn set_healthy(&self, healthy: bool) {
        let was = self.healthy.swap(healthy, Ordering::Relaxed);
        if healthy && !was {
            self.recoveries.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::Relaxed)
    }
}

/// Client-side residency belief for one remote peer's weight store
/// (wire v4): which content hashes this peer is believed to hold, plus
/// the hit/miss accounting the serving report surfaces. Shared between
/// the [`remote::RemoteBackend`] (which maintains it) and the
/// dispatcher (which reads [`Self::contains`] to discount the wire
/// weight term when charging load, via [`CostModel::cost_cached`]).
///
/// It is a *belief*, not ground truth: the peer may have evicted a
/// blob (the `need_weights` round trip corrects that, and
/// [`Self::forget`] records it), and a restarted peer holds nothing —
/// the backend calls [`Self::clear`] on every redial so residency is
/// never assumed across a peer lifetime.
#[derive(Debug, Default)]
pub struct KnownWeights {
    known: Mutex<HashSet<u64>>,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes_saved: AtomicU64,
}

impl KnownWeights {
    pub fn new() -> Arc<Self> {
        Arc::new(KnownWeights::default())
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.known.lock().unwrap().contains(&hash)
    }

    /// Record that the peer confirmed holding `hash` (an `ok` reply to
    /// a hash-only request, or a successful inline ship).
    pub fn mark_known(&self, hash: u64) {
        self.known.lock().unwrap().insert(hash);
    }

    /// Drop one hash — the peer answered `need_weights`, so its store
    /// evicted the blob since we last shipped it.
    pub fn forget(&self, hash: u64) {
        self.known.lock().unwrap().remove(&hash);
    }

    /// Drop everything — called on redial: a restarted peer has an
    /// empty store, and stale residency beliefs would strand hash-only
    /// requests in `need_weights` round trips (or worse, discount
    /// costs for bytes that must actually cross the wire).
    pub fn clear(&self) {
        self.known.lock().unwrap().clear();
    }

    pub fn len(&self) -> usize {
        self.known.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A hash-only request the peer served from residency: `bytes`
    /// weight bytes never crossed the wire.
    pub fn record_hit(&self, bytes: u64) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.bytes_saved.fetch_add(bytes, Ordering::Relaxed);
    }

    /// A blob shipped inline (cold peer, eviction, or redial).
    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// `(hits, misses, wire_weight_bytes_saved)` — flows into
    /// `Report::n_weight_hits` / `n_weight_misses` /
    /// `wire_weight_bytes_saved`.
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.bytes_saved.load(Ordering::Relaxed),
        )
    }
}

/// What kind of convolution a job asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum JobKind {
    /// The paper's standard 3×3 conv: `(C,H,W) ⊛ (K,C,3,3) + (K,)`.
    Standard,
    /// Per-channel 3×3: `(C,H,W) ⊛ (C,3,3) + (C,)`, `spec.k == spec.c`.
    /// ReLU fuses into the core's depthwise path (`spec.relu`).
    Depthwise,
    /// A 1×1 conv pre-lowered to the core's 3×3 dataflow: the image
    /// arrives zero-padded by one pixel and the weights centre-tapped
    /// (see [`crate::hw::depthwise::pointwise_as_3x3`]). Numerically a
    /// standard job; tracked separately so backends can decline the
    /// 11%-MAC-utilisation mapping.
    PointwiseAs3x3,
}

impl JobKind {
    /// Canonical wire-protocol tag (`coordinator::tcp` requests and
    /// replies; `backend::remote` emits it). One mapping for both
    /// sides, so client and server can't drift apart.
    pub fn tag(self) -> &'static str {
        match self {
            JobKind::Standard => "standard",
            JobKind::Depthwise => "depthwise",
            JobKind::PointwiseAs3x3 => "pointwise",
        }
    }
}

/// PSUMs a job contributes in the paper's accounting — kind-aware:
/// depthwise accumulates one PSUM per (window, channel), not per
/// (window, kernel, channel).
pub fn job_psums(spec: &LayerSpec, kind: JobKind) -> u64 {
    match kind {
        JobKind::Depthwise => (spec.conv_oh() * spec.conv_ow() * spec.c) as u64,
        JobKind::Standard | JobKind::PointwiseAs3x3 => spec.psums(),
    }
}

/// What a backend can execute, and in which accumulator mode.
#[derive(Clone, Debug)]
pub struct Capability {
    pub standard3x3: bool,
    pub depthwise: bool,
    pub pointwise_as_3x3: bool,
    /// Accumulator semantics of the outputs this backend produces.
    /// [`Self::allows`] matches it against the job's required mode, so
    /// a mixed pool can carry wrap-8 silicon next to production (I32)
    /// workers without either absorbing the other's traffic.
    pub accum: AccumMode,
    /// Standard/pointwise jobs must satisfy the paper's §4.1 BRAM
    /// layout constraint ([`LayerSpec::paper_compatible`]: `K % 4 == 0`
    /// and the image at least kernel-sized). True for the simulated IP
    /// core — whose `run_layer` rejects such specs — and for remote
    /// peers, whose wire applies the same gate; host CPU workers take
    /// any shape. Depthwise routes through a different entry point and
    /// is unaffected.
    pub paper_specs_only: bool,
    /// `Some(specs)` when the backend can only serve a fixed spec set
    /// (the XLA path serves exactly its compiled artifacts); `None`
    /// means any valid spec of a supported kind. The dispatcher must
    /// honour this — a mask/run mismatch fails the job at run().
    pub spec_allowlist: Option<Vec<LayerSpec>>,
}

impl Capability {
    pub fn supports(&self, kind: JobKind) -> bool {
        match kind {
            JobKind::Standard => self.standard3x3,
            JobKind::Depthwise => self.depthwise,
            JobKind::PointwiseAs3x3 => self.pointwise_as_3x3,
        }
    }

    /// Full routing predicate: kind mask, accumulator-mode match, the
    /// §4.1 gate, and the spec allowlist. `accum` is what the *job*
    /// requires of its reply; a backend only qualifies when it produces
    /// exactly those semantics — an I32 pool must not absorb wrap-8
    /// traffic (it would answer with un-wrapped values) and a wrap-8
    /// core must not absorb production traffic.
    pub fn allows(&self, spec: &LayerSpec, kind: JobKind, accum: AccumMode) -> bool {
        self.supports(kind)
            && self.accum == accum
            && (!self.paper_specs_only
                || kind == JobKind::Depthwise
                || spec.paper_compatible())
            && match &self.spec_allowlist {
                None => true,
                Some(list) => list.contains(spec),
            }
    }
}

/// Dispatcher-side cost estimator. `Copy`, so the pool can weigh queue
/// load on the submit thread while the backend itself lives inside a
/// worker thread. Units are "equivalent busy cycles" of the owning
/// backend — only relative magnitudes within one pool matter for
/// least-loaded balancing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostModel {
    /// The IP core's closed-form schedule (§5.2): standard jobs cost
    /// `windows × ceil(C/4) × K/4 × 8` cycles, depthwise jobs
    /// `windows × ceil(C/4) × 8` (one active PCORE).
    SimCycles,
    /// Naive host loops: ~one unit per MAC (9 per PSUM).
    HostMacs,
    /// Vectorised host runtime: `psums / throughput_factor` units.
    Vectorized { throughput_factor: u64 },
    /// Threaded im2col + blocked GEMM ([`im2col::Im2colBackend`]):
    /// GEMM MACs plus the patch-matrix lowering traffic, retired at
    /// [`IM2COL_MACS_PER_UNIT`] MACs per unit per worker thread.
    Im2col { threads: u64 },
    /// A whole remote machine behind the TCP wire protocol v4
    /// ([`remote::RemoteBackend`]): the peer's `hello` handshake
    /// advertises what its workers *are* (each worker's cost-model
    /// family), so the quote is the job's cost under the peer's fastest
    /// advertised tier ([`RemotePeerClass`]) **divided by the peer's
    /// advertised worker width** — batches now pipeline down one socket
    /// with a bounded in-flight window, so a wider peer genuinely
    /// drains a queue faster — plus the wire traffic (request tensors
    /// out, `full_output` reply back) retired at
    /// [`REMOTE_WORDS_PER_UNIT`] words per unit. The wire term does NOT
    /// divide: the socket is one serial byte stream no matter how many
    /// workers sit behind it, so transfer keeps a remote peer behind a
    /// local core of the same tier on small pools. A peer fronting only
    /// naive host workers quotes host-loop prices, not FPGA-core
    /// prices.
    Remote { workers: u64, class: RemotePeerClass },
}

/// The compute tier a remote peer's `hello` advertised (its workers'
/// cost-model families, collapsed to the fastest tier present). Lets
/// [`CostModel::Remote`] price a peer by what its silicon actually is
/// instead of assuming every remote machine is a rack of IP cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RemotePeerClass {
    /// Simulated IP cores (`sim-cycles`).
    SimCycles,
    /// Vectorised runtime, e.g. the XLA path (`vectorized`); also the
    /// conservative stand-in for a peer's own remote workers, whose
    /// real depth the hello cannot convey.
    Vectorized,
    /// Threaded im2col+GEMM host workers (`im2col`).
    Im2col,
    /// Naive host loops (`host-macs`) — and the fallback for tags this
    /// build does not know, so unknown tiers price conservatively.
    HostMacs,
}

impl RemotePeerClass {
    /// Representative local cost model for this tier (thread/throughput
    /// parameters default to each backend's own defaults — the hello
    /// does not carry them).
    pub fn model(self) -> CostModel {
        match self {
            RemotePeerClass::SimCycles => CostModel::SimCycles,
            RemotePeerClass::Vectorized => CostModel::Vectorized {
                throughput_factor: 1,
            },
            RemotePeerClass::Im2col => CostModel::Im2col { threads: 4 },
            RemotePeerClass::HostMacs => CostModel::HostMacs,
        }
    }

    /// Parse a `hello` worker `model` tag (see [`CostModel::family_tag`]).
    pub fn from_tag(tag: &str) -> Self {
        match tag {
            "sim-cycles" => RemotePeerClass::SimCycles,
            "im2col" => RemotePeerClass::Im2col,
            "vectorized" | "remote" => RemotePeerClass::Vectorized,
            _ => RemotePeerClass::HostMacs,
        }
    }
}

/// MACs one im2col worker thread retires per cost unit, calibrated so
/// `HostMacs / Im2col` matches the blocked-GEMM-vs-naive ratio the
/// `e2e` bench measures on the 32×32 c8→k16 layer (the blocked kernel
/// streams B rows instead of re-walking the image, ≈4× per thread
/// before threading multiplies it). With 4 threads an im2col worker
/// therefore quotes ~1/16 of [`CostModel::HostMacs`] — still above
/// [`CostModel::SimCycles`], so accelerators fill first.
pub const IM2COL_MACS_PER_UNIT: u64 = 4;

/// Wire words one cost unit ships for [`CostModel::Remote`]. Every
/// remote job pays its tensors across the socket both ways; dividing
/// the word count by this keeps the overhead term the same order as
/// the per-core compute share, so a single-worker peer always quotes
/// *more* than a local [`CostModel::SimCycles`] core and the pool
/// prefers local silicon until it queues.
pub const REMOTE_WORDS_PER_UNIT: u64 = 4;

impl CostModel {
    /// Wire tag of this model's family, advertised per worker in the
    /// v2 `hello` (`model` field) so remote coordinators can price this
    /// pool's compute honestly ([`RemotePeerClass::from_tag`] is the
    /// parse side).
    pub fn family_tag(&self) -> &'static str {
        match self {
            CostModel::SimCycles => "sim-cycles",
            CostModel::HostMacs => "host-macs",
            CostModel::Vectorized { .. } => "vectorized",
            CostModel::Im2col { .. } => "im2col",
            CostModel::Remote { .. } => "remote",
        }
    }

    /// [`Self::cost`] with wire-v4 weight residency applied: when the
    /// dispatcher believes the executing peer already holds the job's
    /// weight blob ([`KnownWeights::contains`]), a [`CostModel::Remote`]
    /// quote drops the weight-words wire term — those bytes will not
    /// cross the socket — so least-loaded routing honestly prefers warm
    /// peers. Every other model is residency-blind (local backends
    /// never ship weights over a wire), and the quote never discounts
    /// to zero. Charge and release must pass the *same* flag (the
    /// dispatch-time snapshot on `ConvJob::wire_weights_cached`), or
    /// load accounting leaks when residency changes mid-flight.
    pub fn cost_cached(&self, spec: &LayerSpec, kind: JobKind, weights_cached: bool) -> u64 {
        let base = self.cost(spec, kind);
        if !weights_cached {
            return base;
        }
        match self {
            CostModel::Remote { .. } => {
                let weight_words = match kind {
                    JobKind::Depthwise => spec.c * 9,
                    JobKind::Standard | JobKind::PointwiseAs3x3 => spec.k * spec.c * 9,
                } as u64;
                base.saturating_sub(weight_words / REMOTE_WORDS_PER_UNIT)
                    .max(1)
            }
            _ => base,
        }
    }

    pub fn cost(&self, spec: &LayerSpec, kind: JobKind) -> u64 {
        let windows = (spec.conv_oh() * spec.conv_ow()) as u64;
        let c_rounds = spec.c.div_ceil(N_CORES) as u64;
        match (*self, kind) {
            (CostModel::SimCycles, JobKind::Depthwise) => {
                c_rounds * windows * CYCLES_PER_PSUM_GROUP
            }
            (CostModel::SimCycles, _) => {
                let kernel_groups = (spec.k as u64 / N_PCORES as u64).max(1);
                windows * c_rounds * kernel_groups * CYCLES_PER_PSUM_GROUP
            }
            (CostModel::HostMacs, kind) => job_psums(spec, kind) * 9,
            (CostModel::Vectorized { throughput_factor }, kind) => {
                job_psums(spec, kind) / throughput_factor.max(1) + 1
            }
            (CostModel::Im2col { threads }, kind) => {
                let macs = job_psums(spec, kind) * 9;
                // The lowering writes one patch word per (window, c, tap)
                // — standard/pointwise only; the depthwise path convolves
                // channels directly and never builds a patch matrix.
                let lowering = match kind {
                    JobKind::Depthwise => 0,
                    JobKind::Standard | JobKind::PointwiseAs3x3 => windows * spec.c as u64 * 9,
                };
                ((macs + lowering) / (IM2COL_MACS_PER_UNIT * threads.max(1))).max(1)
            }
            (CostModel::Remote { workers, class }, kind) => {
                // Pipelined service over one socket: the peer fans a
                // batch across its whole worker width, so the honest
                // compute term is one worker's cost divided by that
                // width (never rounded to zero — a remote job is never
                // free).
                let compute_share =
                    (class.model().cost(spec, kind) / workers.max(1)).max(1);
                // Request ships image + weights; the full_output reply
                // ships one word per output element (windows × output
                // channels — NOT per PSUM, which would overcharge the
                // reply leg by a factor of C on standard jobs).
                let weight_words = match kind {
                    JobKind::Depthwise => spec.c * 9,
                    JobKind::Standard | JobKind::PointwiseAs3x3 => spec.k * spec.c * 9,
                } as u64;
                let reply_words = windows
                    * match kind {
                        JobKind::Depthwise => spec.c,
                        JobKind::Standard | JobKind::PointwiseAs3x3 => spec.k,
                    } as u64;
                let wire_words =
                    (spec.c * spec.h * spec.w) as u64 + weight_words + reply_words;
                compute_share + wire_words / REMOTE_WORDS_PER_UNIT + 1
            }
        }
    }
}

/// One conv-layer job in backend-agnostic, borrowed form.
///
/// Shapes by kind — `Standard`/`PointwiseAs3x3`: image `(C,H,W)`,
/// weights `(K,C,3,3)`, bias `(K,)`; `Depthwise`: weights `(C,3,3)`,
/// bias `(C,)`, `spec.k == spec.c`.
#[derive(Debug)]
pub struct JobPayload<'a> {
    pub kind: JobKind,
    pub spec: &'a LayerSpec,
    pub img: &'a Tensor<u8>,
    pub weights: &'a Tensor<u8>,
    pub bias: &'a [i32],
    /// The dispatcher already has this weight set resident on the
    /// executing unit (weight-stationary batching): backends that model
    /// a weight DMA may discount it.
    pub weights_resident: bool,
    /// Telemetry trace id of the request this job serves (0 = tracing
    /// off). Transports propagate it to trace-negotiating peers so
    /// server-side timings can be attributed to the originating
    /// request; compute backends ignore it.
    pub trace_id: u64,
}

impl JobPayload<'_> {
    /// Kind-aware shape contract, shared by the host backends (the
    /// simulator re-validates inside [`crate::hw::IpCore`]): image
    /// matches the spec, weights match the kind's layout, bias length
    /// matches the output-channel count. Backends call this up front so
    /// a malformed payload returns `Err` instead of panicking a pool
    /// worker mid-kernel.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.img.shape() == [self.spec.c, self.spec.h, self.spec.w],
            "image shape {:?} != spec {:?}",
            self.img.shape(),
            self.spec
        );
        match self.kind {
            JobKind::Standard | JobKind::PointwiseAs3x3 => {
                anyhow::ensure!(
                    self.weights.shape() == [self.spec.k, self.spec.c, 3, 3],
                    "weight shape {:?} != spec {:?}",
                    self.weights.shape(),
                    self.spec
                );
                anyhow::ensure!(
                    self.bias.len() == self.spec.k,
                    "bias len {} != K {}",
                    self.bias.len(),
                    self.spec.k
                );
            }
            JobKind::Depthwise => {
                anyhow::ensure!(
                    self.weights.shape() == [self.spec.c, 3, 3],
                    "depthwise weight shape {:?} != (C,3,3) for {:?}",
                    self.weights.shape(),
                    self.spec
                );
                anyhow::ensure!(
                    self.bias.len() == self.spec.c,
                    "depthwise bias len {} != C {}",
                    self.bias.len(),
                    self.spec.c
                );
            }
        }
        Ok(())
    }
}

/// Wire-time decomposition of one remote job, measured by the client
/// and refined by the peer's own reply when it negotiated tracing: the
/// round trip splits into the peer's server-side queue wait, its
/// backend compute, and (by subtraction) the time actually spent on
/// the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireTiming {
    /// Client-measured round trip: request written → reply decoded.
    pub rtt_us: u64,
    /// Peer-reported time the job sat in the peer's queue (0 when the
    /// peer didn't negotiate tracing).
    pub peer_queue_us: u64,
    /// Peer-reported backend compute time (0 when the peer didn't
    /// negotiate tracing).
    pub peer_compute_us: u64,
}

impl WireTiming {
    /// The wire's own share of the round trip: rtt minus everything the
    /// peer accounted for (saturating — clock domains differ).
    pub fn wire_us(&self) -> u64 {
        self.rtt_us
            .saturating_sub(self.peer_queue_us)
            .saturating_sub(self.peer_compute_us)
    }
}

/// What one backend execution produced.
#[derive(Clone, Debug)]
pub struct BackendRun {
    /// Widened i32 output (backends in narrower accumulator modes widen
    /// on readout, exactly like `LayerOutput::into_i32`).
    pub output: Tensor<i32>,
    /// Simulated cycles for hardware backends; modelled equivalent
    /// cycles (the backend's [`CostModel`]) for host paths. Drives
    /// metrics and load accounting uniformly.
    pub cycles: CycleStats,
    /// Wire/remote-compute timing split for jobs that crossed a socket
    /// (`None` for every local backend). Feeds the dispatcher's wire
    /// and compute stage histograms and per-hop trace spans.
    pub wire: Option<WireTiming>,
}

/// A unit that executes conv-layer jobs. `Send` is a supertrait so
/// boxed backends can move into pool worker threads.
pub trait ConvBackend: Send {
    /// Stable identifier (distinct per configuration where it matters,
    /// e.g. `sim-ipcore-wrap8` vs `sim-ipcore-i32`).
    fn name(&self) -> &'static str;

    /// What this backend can run.
    fn capability(&self) -> Capability;

    /// Dispatcher-side cost estimator for this backend.
    fn cost_model(&self) -> CostModel;

    /// Shared liveness flag, for backends whose availability changes at
    /// runtime (the remote backend's probe thread flips it). `None` —
    /// the default — means "always considered healthy"; local backends
    /// don't fail partially.
    fn health(&self) -> Option<Arc<WorkerHealth>> {
        None
    }

    /// Residency belief for the peer's weight store, for backends that
    /// front a wire-v4 remote ([`remote::RemoteBackend`] when the hello
    /// advertised `wcache`). The dispatcher snapshots
    /// [`KnownWeights::contains`] per job to discount the wire weight
    /// term ([`CostModel::cost_cached`]) and aggregates
    /// [`KnownWeights::stats`] into the serving report. `None` — the
    /// default — means "no weight cache on this path".
    fn known_weights(&self) -> Option<Arc<KnownWeights>> {
        None
    }

    /// Estimated cost of one job (provided: delegates to the model).
    fn cost(&self, spec: &LayerSpec, kind: JobKind) -> u64 {
        self.cost_model().cost(spec, kind)
    }

    /// Execute one job. Standard/pointwise jobs return the raw
    /// accumulator output (activation + requant belong to the serving
    /// layer); depthwise fuses ReLU when `spec.relu` is set, matching
    /// the core's depthwise entry point.
    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun>;

    /// Execute a whole same-shape batch, returning one result per job
    /// in order. The default runs jobs serially through [`Self::run`]
    /// — correct for every local backend, where the unit of execution
    /// is the kernel invocation. Transports override it to exploit
    /// batch structure: [`remote::RemoteBackend`] writes the whole
    /// batch down the socket in one buffered burst and reads replies
    /// asynchronously, so the peer's worker width actually overlaps.
    ///
    /// Per-job `Err`s are independent: the dispatcher fails over each
    /// errored job individually while keeping the batch's successes.
    fn run_batch(&mut self, jobs: &[JobPayload]) -> Vec<anyhow::Result<BackendRun>> {
        jobs.iter().map(|j| self.run(j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{QUICKSTART, S52};

    #[test]
    fn sim_cost_matches_s52_cycle_count() {
        // The cost model must agree with the simulator's §5.2 headline.
        let c = CostModel::SimCycles.cost(&S52, JobKind::Standard);
        assert_eq!(c, 1_577_088);
    }

    #[test]
    fn depthwise_psums_drop_the_kernel_axis() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        assert_eq!(job_psums(&spec, JobKind::Standard), 64 * 8 * 8);
        assert_eq!(job_psums(&spec, JobKind::Depthwise), 64 * 8);
    }

    #[test]
    fn capability_masks_by_kind() {
        let cap = Capability {
            standard3x3: true,
            depthwise: false,
            pointwise_as_3x3: true,
            accum: AccumMode::I32,
            paper_specs_only: false,
            spec_allowlist: None,
        };
        assert!(cap.supports(JobKind::Standard));
        assert!(cap.supports(JobKind::PointwiseAs3x3));
        assert!(!cap.supports(JobKind::Depthwise));
        assert!(cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::I32));
    }

    #[test]
    fn allows_requires_exact_accum_match() {
        let mut cap = Capability {
            standard3x3: true,
            depthwise: false,
            pointwise_as_3x3: true,
            accum: AccumMode::I32,
            paper_specs_only: false,
            spec_allowlist: None,
        };
        // An I32 backend must not absorb wrap-8 traffic...
        assert!(cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::I32));
        assert!(!cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::Wrap8));
        // ...and a wrap-8 backend must not absorb production traffic.
        cap.accum = AccumMode::Wrap8;
        assert!(cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::Wrap8));
        assert!(!cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::I32));
    }

    #[test]
    fn spec_allowlist_restricts_routing() {
        let cap = Capability {
            standard3x3: true,
            depthwise: false,
            pointwise_as_3x3: false,
            accum: AccumMode::I32,
            paper_specs_only: false,
            spec_allowlist: Some(vec![QUICKSTART]),
        };
        assert!(cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::I32));
        assert!(!cap.allows(&S52, JobKind::Standard, AccumMode::I32));
        // Kind mask still applies on top of the allowlist.
        assert!(!cap.allows(&QUICKSTART, JobKind::Depthwise, AccumMode::I32));
    }

    #[test]
    fn paper_gate_masks_incompatible_standard_specs_but_not_depthwise() {
        // The §4.1 gate: a sim core or remote peer must decline k%4!=0
        // standard jobs (a host worker in the same pool serves them),
        // while depthwise — a different entry point with no such
        // constraint — routes freely (e.g. c == k == 6).
        let mut cap = Capability {
            standard3x3: true,
            depthwise: true,
            pointwise_as_3x3: true,
            accum: AccumMode::I32,
            paper_specs_only: true,
            spec_allowlist: None,
        };
        let off_paper = LayerSpec::new(4, 8, 8, 6); // K % 4 != 0
        let dw = LayerSpec::new(6, 8, 8, 6);
        assert!(!cap.allows(&off_paper, JobKind::Standard, AccumMode::I32));
        assert!(!cap.allows(&off_paper, JobKind::PointwiseAs3x3, AccumMode::I32));
        assert!(cap.allows(&QUICKSTART, JobKind::Standard, AccumMode::I32));
        assert!(cap.allows(&dw, JobKind::Depthwise, AccumMode::I32));
        // Host workers take any shape.
        cap.paper_specs_only = false;
        assert!(cap.allows(&off_paper, JobKind::Standard, AccumMode::I32));
    }

    #[test]
    fn host_cost_exceeds_sim_cost_per_job() {
        // Golden fallback must look more expensive than an IP core so
        // least-loaded dispatch prefers accelerators until they queue.
        let sim = CostModel::SimCycles.cost(&QUICKSTART, JobKind::Standard);
        let host = CostModel::HostMacs.cost(&QUICKSTART, JobKind::Standard);
        assert!(host > sim, "host {host} vs sim {sim}");
    }

    #[test]
    fn vectorized_cost_is_never_zero() {
        let tiny = LayerSpec::new(1, 3, 3, 4);
        let c = CostModel::Vectorized { throughput_factor: 1_000_000 }.cost(&tiny, JobKind::Standard);
        assert!(c >= 1);
    }

    #[test]
    fn im2col_cost_sits_between_sim_and_naive_host() {
        // Routing intent for mixed pools: accelerators fill first, the
        // threaded im2col worker is the next-cheapest unit, the naive
        // golden loops are last-resort.
        let sim = CostModel::SimCycles.cost(&QUICKSTART, JobKind::Standard);
        let im2col = CostModel::Im2col { threads: 4 }.cost(&QUICKSTART, JobKind::Standard);
        let host = CostModel::HostMacs.cost(&QUICKSTART, JobKind::Standard);
        assert!(sim < im2col, "sim {sim} < im2col {im2col}");
        assert!(im2col < host, "im2col {im2col} < host {host}");
    }

    #[test]
    fn im2col_depthwise_cost_has_no_lowering_term() {
        // Depthwise runs channel loops directly — the quote is pure
        // MACs (windows × C × 9), with no patch-matrix traffic added.
        let spec = LayerSpec::new(8, 10, 10, 8);
        let got = CostModel::Im2col { threads: 1 }.cost(&spec, JobKind::Depthwise);
        assert_eq!(got, 64 * 8 * 9 / IM2COL_MACS_PER_UNIT);
    }

    fn remote_sim() -> CostModel {
        CostModel::Remote {
            workers: 1,
            class: RemotePeerClass::SimCycles,
        }
    }

    #[test]
    fn remote_costs_more_than_local_silicon_of_the_same_tier() {
        // The wire overhead term must keep a remote peer behind a local
        // core of the same silicon, so the pool fills local
        // accelerators before shipping tensors across the network — and
        // the quote is never zero, even for tiny jobs.
        let sim = CostModel::SimCycles.cost(&QUICKSTART, JobKind::Standard);
        let remote = remote_sim().cost(&QUICKSTART, JobKind::Standard);
        assert!(remote > sim, "remote {remote} vs sim {sim}");
        let tiny = LayerSpec::new(1, 3, 3, 4);
        assert!(remote_sim().cost(&tiny, JobKind::Depthwise) >= 1);
    }

    #[test]
    fn remote_depthwise_quote_ships_depthwise_weights() {
        // Depthwise weights are (C,3,3), not (K,C,3,3): the wire term
        // must be smaller than the standard job's on the same spec.
        let spec = LayerSpec::new(8, 10, 10, 8);
        let dw = remote_sim().cost(&spec, JobKind::Depthwise);
        let std = remote_sim().cost(&spec, JobKind::Standard);
        assert!(dw < std, "depthwise {dw} vs standard {std}");
    }

    #[test]
    fn remote_quotes_track_the_peer_tier() {
        // A peer fronting only naive golden workers must quote host
        // prices — routing keeps preferring a local IP core over
        // shipping tensors to a slow remote CPU — and the tiers order
        // the same way their local models do; the hello's `model` tags
        // are what make that honest.
        let sim = CostModel::SimCycles.cost(&QUICKSTART, JobKind::Standard);
        let q = |class: RemotePeerClass| {
            CostModel::Remote { workers: 1, class }.cost(&QUICKSTART, JobKind::Standard)
        };
        assert!(q(RemotePeerClass::HostMacs) > sim);
        assert!(q(RemotePeerClass::SimCycles) < q(RemotePeerClass::Im2col));
        assert!(q(RemotePeerClass::Im2col) < q(RemotePeerClass::HostMacs));
    }

    #[test]
    fn remote_quote_divides_compute_by_worker_width_but_not_wire() {
        // Pipelined batches reach every worker behind the socket, so a
        // wider peer quotes cheaper — but only the compute share
        // divides. The wire term is the same serial byte stream at any
        // width, so the quote floors at transfer cost instead of
        // pretending an infinitely wide peer is free.
        let q = |workers: u64| {
            CostModel::Remote {
                workers,
                class: RemotePeerClass::SimCycles,
            }
            .cost(&S52, JobKind::Standard)
        };
        assert!(q(4) < q(1), "width must cheapen the quote: {} vs {}", q(4), q(1));
        assert!(q(2) < q(1) && q(4) < q(2), "monotone in width");
        // At absurd widths the compute share floors at 1 and the quote
        // converges to the wire term, which is far above zero.
        let wire_floor = q(1_000_000);
        assert!(wire_floor > 100, "quote keeps the wire term: {wire_floor}");
        // Degenerate width never divides by zero or quotes zero.
        assert!(q(0) >= 1 && q(0) == q(1));
    }

    #[test]
    fn peer_class_tags_round_trip_cost_model_families() {
        for model in [
            CostModel::SimCycles,
            CostModel::HostMacs,
            CostModel::Vectorized { throughput_factor: 3 },
            CostModel::Im2col { threads: 2 },
        ] {
            let class = RemotePeerClass::from_tag(model.family_tag());
            assert_eq!(
                class.model().family_tag(),
                model.family_tag(),
                "{model:?} must survive the hello round trip"
            );
        }
        // A peer's own remote workers and unknown tiers get priced
        // conservatively rather than rejected.
        assert_eq!(
            RemotePeerClass::from_tag("remote"),
            RemotePeerClass::Vectorized
        );
        assert_eq!(
            RemotePeerClass::from_tag("warp-drive"),
            RemotePeerClass::HostMacs
        );
    }

    #[test]
    fn cached_remote_quote_drops_exactly_the_weight_wire_term() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        for kind in [JobKind::Standard, JobKind::Depthwise] {
            let cold = remote_sim().cost(&spec, kind);
            let warm = remote_sim().cost_cached(&spec, kind, true);
            let weight_words = match kind {
                JobKind::Depthwise => 8 * 9u64,
                _ => 8 * 8 * 9,
            };
            assert_eq!(cold - warm, weight_words / REMOTE_WORDS_PER_UNIT);
            // An uncached job quotes the full price.
            assert_eq!(remote_sim().cost_cached(&spec, kind, false), cold);
        }
    }

    #[test]
    fn cached_quote_never_discounts_local_models_or_hits_zero() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        for model in [
            CostModel::SimCycles,
            CostModel::HostMacs,
            CostModel::Im2col { threads: 4 },
        ] {
            assert_eq!(
                model.cost_cached(&spec, JobKind::Standard, true),
                model.cost(&spec, JobKind::Standard),
                "{model:?} has no wire weight term to discount"
            );
        }
        // Degenerate case: a quote dominated by its weight term still
        // floors at 1 instead of going free.
        let tiny = LayerSpec::new(64, 3, 3, 64);
        let warm = CostModel::Remote {
            workers: 1_000_000,
            class: RemotePeerClass::SimCycles,
        }
        .cost_cached(&tiny, JobKind::Standard, true);
        assert!(warm >= 1);
    }

    #[test]
    fn known_weights_tracks_residency_and_stats() {
        let k = KnownWeights::new();
        assert!(k.is_empty() && !k.contains(7));
        k.mark_known(7);
        k.mark_known(9);
        assert!(k.contains(7) && k.contains(9));
        assert_eq!(k.len(), 2);
        // A need_weights reply drops exactly the evicted hash.
        k.forget(9);
        assert!(k.contains(7) && !k.contains(9));
        // Redial drops everything.
        k.clear();
        assert!(k.is_empty());
        k.record_miss();
        k.record_hit(2304);
        k.record_hit(2304);
        assert_eq!(k.stats(), (2, 1, 4608));
    }

    #[test]
    fn im2col_cost_scales_down_with_threads_and_never_hits_zero() {
        let spec = LayerSpec::new(8, 10, 10, 8);
        let t1 = CostModel::Im2col { threads: 1 }.cost(&spec, JobKind::Standard);
        let t4 = CostModel::Im2col { threads: 4 }.cost(&spec, JobKind::Standard);
        assert!(t4 < t1, "threads must cheapen the quote: {t4} vs {t1}");
        let tiny = LayerSpec::new(1, 3, 3, 4);
        assert!(CostModel::Im2col { threads: 1_000_000 }.cost(&tiny, JobKind::Depthwise) >= 1);
    }
}
