//! Computing core (§4.2 "Multi-Kernel Computing Core"): four PCOREs fed
//! by one Image Loader (window broadcast) and one Weight Loader (four
//! kernel-channels staged in parallel from the interleaved weight BMGs).
//!
//! One *sweep* = one (kernel group, channel) pass over every 3×3 window
//! of the image: the paper's 8-cycle step produces the 4 PSUMs of one
//! window, which the core accumulates into the output BMGs (kernel
//! `4*group + j` → output BMG `j`, conflict-free).

use super::bram::{AccumWord, ImageBrams, OutputBrams, WeightBrams};
use super::loader::{ImageLoader, WeightLoader};
use super::pcore::{PCore, Psum};
use super::waveform::WaveTrace;
use super::AccumMode;
use crate::paper::{CYCLES_PER_PSUM_GROUP, KH, KW, N_PCORES};

/// Output word that knows which accumulator mode produces it.
pub trait PsumWord: AccumWord {
    const MODE: AccumMode;
    fn from_psum(p: Psum) -> Self;
}

impl PsumWord for u8 {
    const MODE: AccumMode = AccumMode::Wrap8;
    fn from_psum(p: Psum) -> Self {
        match p {
            Psum::Wrap8(v) => v,
            Psum::I32(v) => (v & 0xFF) as u8,
        }
    }
}

impl PsumWord for i32 {
    const MODE: AccumMode = AccumMode::I32;
    fn from_psum(p: Psum) -> Self {
        match p {
            Psum::I32(v) => v,
            Psum::Wrap8(v) => v as i32,
        }
    }
}

/// Per-sweep cycle accounting (stage-1 load vs stage-2 compute; the
/// pipeline model in [`super::pipeline`] combines them).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepCycles {
    /// Stage-2: 8 cycles per window (the §5.2 schedule).
    pub compute: u64,
    /// Stage-1: image-window fetches (5 for a fresh window, 2 per slide).
    pub image_load: u64,
    /// Stage-1: weight staging for this (group, channel).
    pub weight_load: u64,
    /// Windows processed.
    pub windows: u64,
}

/// One computing core.
#[derive(Clone, Debug)]
pub struct ComputeCore {
    /// Which channel quarter this core owns (§4.2 multi-channel).
    pub id: usize,
    pub pcores: [PCore; N_PCORES],
    pub image_loader: ImageLoader,
    pub weight_loader: WeightLoader,
}

impl ComputeCore {
    pub fn new(id: usize) -> Self {
        ComputeCore {
            id,
            pcores: std::array::from_fn(|_| PCore::new()),
            image_loader: ImageLoader::new(),
            weight_loader: WeightLoader::new(),
        }
    }

    /// One (kernel group, channel) sweep over all output windows,
    /// accumulating PSUMs into the output BMGs. Optionally records the
    /// Fig. 6 waveform signals per window step.
    ///
    /// Untraced sweeps take the bulk fast path (`sweep_fast`): identical
    /// results, cycle figures and port counts, ~6× less host time
    /// (EXPERIMENTS.md §Perf) — equivalence is asserted by
    /// `fast_path_equals_stepping_path` below and the property suite.
    #[allow(clippy::too_many_arguments)]
    pub fn sweep<T: PsumWord>(
        &mut self,
        img: &mut ImageBrams,
        wgt: &mut WeightBrams,
        out: &mut OutputBrams<T>,
        group: usize,
        ch: usize,
        mut trace: Option<&mut WaveTrace>,
    ) -> SweepCycles {
        if trace.is_none() {
            return self.sweep_fast(img, wgt, out, group, ch);
        }
        let (_, h, w) = img.dims();
        let (oh, ow) = (h - KH + 1, w - KW + 1);
        let mut cycles = SweepCycles::default();

        // Stage weights for this (group, channel); they stay resident for
        // the whole sweep (weight stationary).
        let wl_before = self.weight_loader.load_cycles;
        let kernel_weights = self.weight_loader.fetch_group(wgt, group, ch);
        for (j, pc) in self.pcores.iter_mut().enumerate() {
            pc.load_weights(kernel_weights[j]);
        }
        cycles.weight_load = self.weight_loader.load_cycles - wl_before;

        for y in 0..oh {
            for x in 0..ow {
                let il_before = self.image_loader.load_cycles;
                let window = self.image_loader.fetch(img, ch, y, x);
                cycles.image_load += self.image_loader.load_cycles - il_before;

                let mut psums = [Psum::Wrap8(0); N_PCORES];
                for (j, pc) in self.pcores.iter_mut().enumerate() {
                    let p = pc.compute(&window, T::MODE);
                    psums[j] = p;
                    out.accumulate(N_PCORES * group + j, y, x, T::from_psum(p));
                }
                cycles.compute += CYCLES_PER_PSUM_GROUP;
                cycles.windows += 1;

                if let Some(tr) = trace.as_deref_mut() {
                    tr.record_window_step(self, &window, &psums, cycles.compute);
                }
            }
        }
        cycles
    }

    /// Bulk fast path (§Perf): whole-plane borrow + row-granular output
    /// accumulation. Produces byte-identical outputs, cycle stats and
    /// BMG port counts to the per-window path above.
    fn sweep_fast<T: PsumWord>(
        &mut self,
        img: &mut ImageBrams,
        wgt: &mut WeightBrams,
        out: &mut OutputBrams<T>,
        group: usize,
        ch: usize,
    ) -> SweepCycles {
        let (_, h, w) = img.dims();
        let (oh, ow) = (h - KH + 1, w - KW + 1);
        let mut cycles = SweepCycles::default();

        // Weights: same staging as the stepping path.
        let wl_before = self.weight_loader.load_cycles;
        let kernel_weights = self.weight_loader.fetch_group(wgt, group, ch);
        for (j, pc) in self.pcores.iter_mut().enumerate() {
            pc.load_weights(kernel_weights[j]);
        }
        cycles.weight_load = self.weight_loader.load_cycles - wl_before;

        // Image: closed-form loader accounting + direct plane borrow.
        let (_, load_cycles) = self.image_loader.add_sweep_bulk(oh, ow);
        cycles.image_load = load_cycles;
        let plane = img.plane_bulk(ch, (oh * (9 + (ow - 1) * 3)) as u64);

        // Compute: per kernel per output row, then one bulk accumulate.
        let mut row = vec![T::default(); ow];
        for (j, kw) in kernel_weights.iter().enumerate() {
            let k = N_PCORES * group + j;
            let wv: [i32; 9] = std::array::from_fn(|i| kw[i] as i32);
            for y in 0..oh {
                let r0 = &plane[y * w..y * w + w];
                let r1 = &plane[(y + 1) * w..(y + 1) * w + w];
                let r2 = &plane[(y + 2) * w..(y + 2) * w + w];
                for (x, slot) in row.iter_mut().enumerate() {
                    let acc = wv[0] * r0[x] as i32
                        + wv[1] * r0[x + 1] as i32
                        + wv[2] * r0[x + 2] as i32
                        + wv[3] * r1[x] as i32
                        + wv[4] * r1[x + 1] as i32
                        + wv[5] * r1[x + 2] as i32
                        + wv[6] * r2[x] as i32
                        + wv[7] * r2[x + 1] as i32
                        + wv[8] * r2[x + 2] as i32;
                    *slot = T::from_psum(Psum::I32(acc));
                }
                out.accumulate_row(k, y, &row);
            }
            self.pcores[j].psum_count += (oh * ow) as u64;
        }

        cycles.windows = (oh * ow) as u64;
        cycles.compute = cycles.windows * CYCLES_PER_PSUM_GROUP;
        cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{golden, Tensor};
    use crate::util::prng::Prng;

    fn setup(
        c: usize,
        h: usize,
        w: usize,
        k: usize,
        seed: u64,
    ) -> (Tensor<u8>, Tensor<u8>, ImageBrams, WeightBrams) {
        let mut rng = Prng::new(seed);
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let wts = Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256));
        let mut ib = ImageBrams::new(c, h, w);
        ib.load_image(&img);
        let mut wb = WeightBrams::new(k, c);
        wb.load_weights(&wts);
        (img, wts, ib, wb)
    }

    #[test]
    fn single_channel_sweep_matches_golden_wrap8() {
        let (img, wts, mut ib, mut wb) = setup(1, 5, 5, 4, 10);
        let mut out = OutputBrams::<u8>::new(4, 3, 3);
        out.preload_bias(&[0; 4]);
        let mut core = ComputeCore::new(0);
        let cyc = core.sweep(&mut ib, &mut wb, &mut out, 0, 0, None);
        let got = out.readout();
        let want = golden::conv3x3_wrap8(&img, &wts, &[0; 4]);
        assert_eq!(got.data(), want.data());
        assert_eq!(cyc.windows, 9);
        assert_eq!(cyc.compute, 9 * CYCLES_PER_PSUM_GROUP);
    }

    #[test]
    fn multi_channel_accumulation_matches_golden_i32() {
        let (img, wts, mut ib, mut wb) = setup(4, 6, 7, 4, 11);
        let mut out = OutputBrams::<i32>::new(4, 4, 5);
        out.preload_bias(&[5, -3, 0, 9]);
        let mut core = ComputeCore::new(0);
        for ch in 0..4 {
            core.sweep(&mut ib, &mut wb, &mut out, 0, ch, None);
        }
        let got = out.readout();
        let want = golden::conv3x3_i32(&img, &wts, &[5, -3, 0, 9], false);
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn kernel_groups_hit_disjoint_outputs() {
        let (img, wts, mut ib, mut wb) = setup(1, 4, 4, 8, 12);
        let mut out = OutputBrams::<i32>::new(8, 2, 2);
        out.preload_bias(&[0; 8]);
        let mut core = ComputeCore::new(0);
        core.sweep(&mut ib, &mut wb, &mut out, 0, 0, None); // kernels 0..4
        core.sweep(&mut ib, &mut wb, &mut out, 1, 0, None); // kernels 4..8
        let got = out.readout();
        let want = golden::conv3x3_i32(&img, &wts, &[0; 8], false);
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn sweep_cycle_accounting() {
        let (_, _, mut ib, mut wb) = setup(1, 5, 7, 4, 13);
        let mut out = OutputBrams::<i32>::new(4, 3, 5);
        out.preload_bias(&[0; 4]);
        let mut core = ComputeCore::new(0);
        let cyc = core.sweep(&mut ib, &mut wb, &mut out, 0, 0, None);
        // 3 rows x 5 cols = 15 windows; each row: 1 fresh (5cy) + 4 slides (2cy).
        assert_eq!(cyc.windows, 15);
        assert_eq!(cyc.compute, 15 * 8);
        assert_eq!(cyc.image_load, 3 * (5 + 4 * 2));
        assert_eq!(cyc.weight_load, 5);
    }

    #[test]
    fn fast_path_equals_stepping_path() {
        // Same sweep through both code paths: identical outputs, cycle
        // stats and BMG port counters (the §Perf equivalence contract).
        for seed in [15u64, 16, 17] {
            let (_, _, mut ib_a, mut wb_a) = setup(3, 7, 9, 8, seed);
            let (_, _, mut ib_b, mut wb_b) = setup(3, 7, 9, 8, seed);
            let mut out_a = OutputBrams::<i32>::new(8, 5, 7);
            out_a.preload_bias(&[1; 8]);
            let mut out_b = OutputBrams::<i32>::new(8, 5, 7);
            out_b.preload_bias(&[1; 8]);
            let mut core_a = ComputeCore::new(0);
            let mut core_b = ComputeCore::new(0);
            for g in 0..2 {
                for ch in 0..3 {
                    // Fast path (no trace).
                    let ca = core_a.sweep(&mut ib_a, &mut wb_a, &mut out_a, g, ch, None);
                    // Stepping path (forced by a throwaway trace).
                    let mut tr = WaveTrace::fig6();
                    let cb = core_b.sweep(&mut ib_b, &mut wb_b, &mut out_b, g, ch, Some(&mut tr));
                    assert_eq!(ca, cb, "cycle stats, seed {seed} g{g} ch{ch}");
                }
            }
            assert_eq!(out_a.readout().data(), out_b.readout().data(), "seed {seed}");
            assert_eq!(
                core_a.image_loader.fetched, core_b.image_loader.fetched,
                "loader fetch accounting, seed {seed}"
            );
            for (ba, bb) in ib_a.banks.iter().zip(&ib_b.banks) {
                assert_eq!(ba.reads, bb.reads, "image port counts, seed {seed}");
            }
            for (ba, bb) in out_a.banks.iter().zip(&out_b.banks) {
                assert_eq!(ba.reads + ba.writes, bb.reads + bb.writes, "output ports, seed {seed}");
            }
        }
    }

    #[test]
    fn fast_path_equals_stepping_path_wrap8() {
        let (_, _, mut ib_a, mut wb_a) = setup(2, 6, 6, 4, 18);
        let (_, _, mut ib_b, mut wb_b) = setup(2, 6, 6, 4, 18);
        let mut out_a = OutputBrams::<u8>::new(4, 4, 4);
        out_a.preload_bias(&[7; 4]);
        let mut out_b = OutputBrams::<u8>::new(4, 4, 4);
        out_b.preload_bias(&[7; 4]);
        let mut core_a = ComputeCore::new(0);
        let mut core_b = ComputeCore::new(0);
        for ch in 0..2 {
            core_a.sweep(&mut ib_a, &mut wb_a, &mut out_a, 0, ch, None);
            let mut tr = WaveTrace::fig6();
            core_b.sweep(&mut ib_b, &mut wb_b, &mut out_b, 0, ch, Some(&mut tr));
        }
        assert_eq!(out_a.readout().data(), out_b.readout().data());
    }

    #[test]
    fn weight_stationary_across_windows() {
        let (_, _, mut ib, mut wb) = setup(2, 5, 5, 4, 14);
        let mut out = OutputBrams::<i32>::new(4, 3, 3);
        out.preload_bias(&[0; 4]);
        let mut core = ComputeCore::new(0);
        core.sweep(&mut ib, &mut wb, &mut out, 0, 0, None);
        // One weight staging for 9 windows: loads == 1.
        assert_eq!(core.weight_loader.loads, 1);
        core.sweep(&mut ib, &mut wb, &mut out, 0, 1, None);
        assert_eq!(core.weight_loader.loads, 2);
    }
}
