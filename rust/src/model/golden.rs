//! Golden reference convolutions — the anchor every other compute path
//! (hw simulator, Pallas kernel via XLA, fused CNN artifact) is tested
//! against. Deliberately written as naive loops: slow, obvious, and
//! independent of the implementations under test.
//!
//! Two accumulator modes mirror DESIGN.md §5:
//! * [`conv3x3_i32`] — u8 data, 32-bit accumulation (production mode,
//!   and what the Pallas kernel computes in exact f32);
//! * [`conv3x3_wrap8`] — the silicon semantics of Fig. 6: PSUMs wrap
//!   modulo 256.

use super::tensor::Tensor;
use crate::paper::{KH, KW};

/// u8 image `(C,H,W)` ⊛ u8 weights `(K,C,3,3)` + i32 bias `(K,)`,
/// wide accumulation, valid padding. Optional fused ReLU.
pub fn conv3x3_i32(
    img: &Tensor<u8>,
    w: &Tensor<u8>,
    bias: &[i32],
    relu: bool,
) -> Tensor<i32> {
    let (c, h, width) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let k = w.shape()[0];
    assert_eq!(w.shape(), &[k, c, KH, KW], "weight shape");
    assert_eq!(bias.len(), k, "bias len");
    let (oh, ow) = (h - KH + 1, width - KW + 1);
    let mut out = Tensor::<i32>::zeros(&[k, oh, ow]);
    for ki in 0..k {
        for y in 0..oh {
            for x in 0..ow {
                let mut acc: i32 = bias[ki];
                for ci in 0..c {
                    for dy in 0..KH {
                        for dx in 0..KW {
                            acc += img.at3(ci, y + dy, x + dx) as i32
                                * w.at4(ki, ci, dy, dx) as i32;
                        }
                    }
                }
                if relu && acc < 0 {
                    acc = 0;
                }
                out.set3(ki, y, x, acc);
            }
        }
    }
    out
}

/// Bit-exact Fig. 6 semantics: u8 inputs, PSUM wraps modulo 256, bias
/// pre-loaded into the accumulator (the paper's output-BRAM preload).
pub fn conv3x3_wrap8(img: &Tensor<u8>, w: &Tensor<u8>, bias: &[u8]) -> Tensor<u8> {
    let (c, h, width) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let k = w.shape()[0];
    assert_eq!(w.shape(), &[k, c, KH, KW], "weight shape");
    assert_eq!(bias.len(), k, "bias len");
    let (oh, ow) = (h - KH + 1, width - KW + 1);
    let mut out = Tensor::<u8>::zeros(&[k, oh, ow]);
    for ki in 0..k {
        for y in 0..oh {
            for x in 0..ow {
                let mut acc: u8 = bias[ki];
                for ci in 0..c {
                    for dy in 0..KH {
                        for dx in 0..KW {
                            acc = acc.wrapping_add(
                                img.at3(ci, y + dy, x + dx)
                                    .wrapping_mul(w.at4(ki, ci, dy, dx)),
                            );
                        }
                    }
                }
                out.set3(ki, y, x, acc);
            }
        }
    }
    out
}

/// 2x2/s2 max pool, floor semantics (odd trailing row/col dropped).
pub fn maxpool2x2<T: Copy + Ord + Default>(img: &Tensor<T>) -> Tensor<T> {
    let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::<T>::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for y in 0..oh {
            for x in 0..ow {
                let m = img
                    .at3(ci, 2 * y, 2 * x)
                    .max(img.at3(ci, 2 * y, 2 * x + 1))
                    .max(img.at3(ci, 2 * y + 1, 2 * x))
                    .max(img.at3(ci, 2 * y + 1, 2 * x + 1));
                out.set3(ci, y, x, m);
            }
        }
    }
    out
}

/// f32 variant of the golden conv for checking XLA outputs directly
/// (the artifacts ship f32 carriers of exact integers).
pub fn conv3x3_f32(img: &Tensor<f32>, w: &Tensor<f32>, bias: &[f32], relu: bool) -> Tensor<f32> {
    let (c, h, width) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let k = w.shape()[0];
    let (oh, ow) = (h - KH + 1, width - KW + 1);
    let mut out = Tensor::<f32>::zeros(&[k, oh, ow]);
    for ki in 0..k {
        for y in 0..oh {
            for x in 0..ow {
                let mut acc = bias[ki];
                for ci in 0..c {
                    for dy in 0..KH {
                        for dx in 0..KW {
                            acc += img.at3(ci, y + dy, x + dx) * w.at4(ki, ci, dy, dx);
                        }
                    }
                }
                if relu {
                    acc = acc.max(0.0);
                }
                out.set3(ki, y, x, acc);
            }
        }
    }
    out
}

/// f32 max pool (for the XLA parity path; f32 is not `Ord`).
pub fn maxpool2x2_f32(img: &Tensor<f32>) -> Tensor<f32> {
    let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::<f32>::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for y in 0..oh {
            for x in 0..ow {
                let m = img
                    .at3(ci, 2 * y, 2 * x)
                    .max(img.at3(ci, 2 * y, 2 * x + 1))
                    .max(img.at3(ci, 2 * y + 1, 2 * x))
                    .max(img.at3(ci, 2 * y + 1, 2 * x + 1));
                out.set3(ci, y, x, m);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn small_case(seed: u64, c: usize, h: usize, w: usize, k: usize) -> (Tensor<u8>, Tensor<u8>) {
        let mut rng = Prng::new(seed);
        let img = Tensor::from_vec(&[c, h, w], rng.bytes_below(c * h * w, 256));
        let wts = Tensor::from_vec(&[k, c, 3, 3], rng.bytes_below(k * c * 9, 256));
        (img, wts)
    }

    #[test]
    fn identity_kernel_extracts_center() {
        // Kernel = 1 at center tap, zero elsewhere, one channel.
        let img = Tensor::from_vec(&[1, 3, 3], (1..=9u8).collect());
        let mut wdata = vec![0u8; 9];
        wdata[4] = 1; // (dy=1, dx=1)
        let w = Tensor::from_vec(&[1, 1, 3, 3], wdata);
        let out = conv3x3_i32(&img, &w, &[0], false);
        assert_eq!(out.data(), &[5]); // the center pixel
    }

    #[test]
    fn bias_preload_equals_addition() {
        let (img, w) = small_case(3, 2, 5, 5, 4);
        let zero = conv3x3_i32(&img, &w, &[0; 4], false);
        let biased = conv3x3_i32(&img, &w, &[7, -3, 0, 100], false);
        for ki in 0..4 {
            let b = [7, -3, 0, 100][ki];
            for y in 0..3 {
                for x in 0..3 {
                    assert_eq!(biased.at3(ki, y, x), zero.at3(ki, y, x) + b);
                }
            }
        }
    }

    #[test]
    fn wrap8_is_i32_mod_256() {
        let (img, w) = small_case(5, 3, 6, 7, 4);
        let bias8 = [1u8, 2, 3, 4];
        let bias32: Vec<i32> = bias8.iter().map(|&b| b as i32).collect();
        let wide = conv3x3_i32(&img, &w, &bias32, false);
        let wrap = conv3x3_wrap8(&img, &w, &bias8);
        for (a, b) in wide.data().iter().zip(wrap.data()) {
            assert_eq!((*a as u32 % 256) as u8, *b);
        }
    }

    #[test]
    fn relu_clamps_negative() {
        // u8 inputs can't go negative, but bias can.
        let (img, w) = small_case(6, 1, 3, 3, 4);
        let out = conv3x3_i32(&img, &w, &[-1_000_000; 4], true);
        assert!(out.data().iter().all(|&v| v >= 0));
    }

    #[test]
    fn maxpool_floor_and_values() {
        let img = Tensor::from_vec(&[1, 3, 3], vec![1u8, 2, 3, 4, 5, 6, 7, 8, 9]);
        let out = maxpool2x2(&img);
        assert_eq!(out.shape(), &[1, 1, 1]);
        assert_eq!(out.data(), &[5]); // max of the top-left 2x2
    }

    #[test]
    fn f32_matches_i32_on_exact_ints() {
        let (img, w) = small_case(9, 4, 8, 8, 4);
        let bias = [10i32, -5, 0, 3];
        let wide = conv3x3_i32(&img, &w, &bias, true);
        let f = conv3x3_f32(
            &img.to_f32(),
            &w.map(|v| v as f32),
            &bias.map(|b| b as f32),
            true,
        );
        for (a, b) in wide.data().iter().zip(f.data()) {
            assert_eq!(*a as f32, *b);
        }
    }
}
