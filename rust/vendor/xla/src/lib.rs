//! Stub of the `xla-rs` PJRT bindings.
//!
//! The offline build environment has no XLA/PJRT shared library, so
//! this crate mirrors the exact API surface `runtime::executor` calls
//! and fails at the earliest possible point: [`PjRtClient::cpu`]
//! returns an error, which `XlaRuntime::new` propagates, and every
//! caller in the repository already treats that as "XLA unavailable —
//! skip". Nothing past client construction is ever reached.
//!
//! A deployment with a real PJRT link swaps this crate for the real
//! bindings with a Cargo `[patch]` entry; no source changes needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (Display-able, boxable).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_unavailable() -> Error {
    Error(
        "PJRT is not linked in this build (vendored xla stub); \
         patch in the real xla-rs bindings to execute HLO artifacts"
            .to_string(),
    )
}

/// PJRT client handle. The stub can never be constructed.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(stub_unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_unavailable())
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(stub_unavailable())
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_unavailable())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_unavailable())
    }
}

/// Host literal (tensor value).
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(stub_unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(stub_unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(stub_unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub client must not exist");
        assert!(err.to_string().contains("PJRT is not linked"));
    }

    #[test]
    fn literal_roundtrip_surface_compiles() {
        // Only the shapes of the API matter; behaviour is unreachable
        // behind the failing client constructor.
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
    }
}
