//! Experiment S52 (DESIGN.md §4): the §5.2 throughput arithmetic —
//! 3,154,176 PSUMs, 1,577,088 cycles, 0.01408 s @ 112 MHz, 0.224 GOPS
//! per IP core, 4.48 GOPS at the paper's 20-core deployment.

use repro::hw::ip_core::{gops_mac, gops_psum};
use repro::hw::{IpCore, IpCoreConfig};
use repro::model::{Tensor, S52};
use repro::paper::{FREQ_Z2_HZ, GOPS_20, GOPS_SINGLE, MAX_CORES_Z2};
use repro::util::prng::Prng;

fn run_s52() -> repro::hw::LayerRun {
    let mut rng = Prng::new(52);
    let img = Tensor::from_vec(
        &[S52.c, S52.h, S52.w],
        rng.bytes_below(S52.c * S52.h * S52.w, 256),
    );
    let wts = Tensor::from_vec(&[S52.k, S52.c, 3, 3], rng.bytes_below(S52.k * S52.c * 9, 256));
    IpCore::new(IpCoreConfig::default())
        .run_layer(&S52, &img, &wts, &vec![0; S52.k], None)
        .expect("S52 runs")
}

#[test]
fn psum_count_is_3_154_176() {
    assert_eq!(S52.psums(), 3_154_176);
}

#[test]
fn compute_cycles_are_1_577_088() {
    let run = run_s52();
    assert_eq!(run.cycles.compute, 1_577_088);
    // = psums / 2 per cycle (16 PSUMs / 8 cycles across 4 cores).
    assert_eq!(run.cycles.compute, S52.psums() / 2);
}

#[test]
fn time_at_112mhz_is_0_01408_s() {
    let run = run_s52();
    let secs = run.cycles.compute as f64 / FREQ_Z2_HZ as f64;
    assert!((secs - 0.01408).abs() < 1e-5, "{secs}");
}

#[test]
fn single_core_is_0_224_gops() {
    let run = run_s52();
    let gops = gops_psum(S52.psums(), run.cycles.compute, FREQ_Z2_HZ);
    assert!((gops - GOPS_SINGLE).abs() < 1e-3, "{gops}");
    // True arithmetic accounting: 9 MACs = 18 ops per PSUM.
    let mac_gops = gops_mac(S52.psums(), run.cycles.compute, FREQ_Z2_HZ);
    assert!((mac_gops - GOPS_SINGLE * 18.0).abs() < 1e-2);
}

#[test]
fn twenty_cores_reach_4_48_gops() {
    let run = run_s52();
    let single = gops_psum(S52.psums(), run.cycles.compute, FREQ_Z2_HZ);
    let twenty = single * MAX_CORES_Z2 as f64;
    assert!((twenty - GOPS_20).abs() < 1e-2, "{twenty}");
}

#[test]
fn scaling_is_linear_in_cores() {
    // Independent cores process independent layers: GOPS must scale
    // exactly linearly in this model (no shared-resource contention in
    // the paper's deployment either — separate BRAM sets per core).
    let run = run_s52();
    let single = gops_psum(S52.psums(), run.cycles.compute, FREQ_Z2_HZ);
    for n in 1..=MAX_CORES_Z2 {
        let scaled = single * n as f64;
        assert!((scaled / single - n as f64).abs() < 1e-12);
    }
}

#[test]
fn pipeline_overhead_is_negligible_at_s52_scale() {
    // The paper counts compute cycles only; our model's visible fill is
    // a few cycles — confirm it is < 0.01% of the total.
    let run = run_s52();
    assert!(run.cycles.load_visible as f64 / (run.cycles.compute as f64) < 1e-4);
    // The hidden (pipelined-away) load time is substantial — the
    // pipeline is pulling real weight.
    assert!(run.cycles.load_hidden > run.cycles.compute / 10);
}
