//! Minimal JSON parser for `artifacts/manifest.json` and the TCP wire
//! protocol's control frames.
//!
//! The offline build has no `serde_json`, and this parser is the only
//! JSON this system reads, so a small recursive-descent parser is the
//! honest dependency-free answer. Supports the full JSON grammar except
//! `\u` surrogate pairs (the manifest is ASCII).
//!
//! Integer literals without a fraction or exponent parse to
//! [`Json::Int`] and round-trip **exactly** up to `i64::MAX` — the wire
//! protocol carries request ids and checksums as integers, and routing
//! them through `f64` silently corrupts values above 2^53. Numeric
//! equality is cross-variant: `Int(42) == Num(42.0)`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    /// Exact integer (id/checksum-grade). Emitted without a fraction.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            (Json::Int(a), Json::Int(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            // Cross-variant numeric equality: an emitted Int re-parses
            // as Int, but values built via `Json::num` compare equal to
            // it when they denote the same number. Outside the f64-exact
            // window (|i| > 2^53, same rule as `as_i64`) the comparison
            // is refused: `i as f64` rounds there, and Int(2^53+1) must
            // not compare equal to a Num it does not exactly equal.
            (Json::Int(i), Json::Num(f)) | (Json::Num(f), Json::Int(i)) => {
                i.unsigned_abs() <= 1 << 53 && *i as f64 == *f
            }
            _ => false,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Exact signed integer. `Int` is returned verbatim; a `Num` only
    /// qualifies when it is a whole number inside the f64-exact range
    /// (|n| ≤ 2^53), so precision loss can never slip through silently.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    /// Exact unsigned integer (see [`Json::as_i64`] for the `Num` rule).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` convenience: `get(&["a", "b"])`.
    pub fn get(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.as_obj()?.get(*key)?;
        }
        Some(cur)
    }

    /// Compact serialisation (the TCP wire format's emitter half).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = std::fmt::Write::write_fmt(s, format_args!("{i}"));
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = std::fmt::Write::write_fmt(s, format_args!("{}", *n as i64));
                } else {
                    let _ = std::fmt::Write::write_fmt(s, format_args!("{n}"));
                }
            }
            Json::Str(v) => {
                s.push('"');
                for ch in v.chars() {
                    match ch {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\r' => s.push_str("\\r"),
                        '\t' => s.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = std::fmt::Write::write_fmt(
                                s,
                                format_args!("\\u{:04x}", c as u32),
                            );
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(items) => {
                s.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    v.write_to(s);
                }
                s.push(']');
            }
            Json::Obj(map) => {
                s.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write_to(s);
                    s.push(':');
                    v.write_to(s);
                }
                s.push('}');
            }
        }
    }

    /// Builders for the emitter side.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Exact integer builder — the only correct choice for wire ids,
    /// checksums and cycle counts, which may exceed f64's 2^53 window.
    pub fn int(i: impl Into<i64>) -> Json {
        Json::Int(i.into())
    }

    /// Exact u64 builder. Values above `i64::MAX` (none of the wire
    /// fields legitimately reach 2^63) degrade to the closest f64.
    pub fn uint(u: u64) -> Json {
        match i64::try_from(u) {
            Ok(i) => Json::Int(i),
            Err(_) => Json::Num(u as f64),
        }
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_u64(xs: impl IntoIterator<Item = u64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::uint).collect())
    }

    pub fn arr_i64(xs: impl IntoIterator<Item = i64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::Int).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).ok_or_else(|| self.err("bad \\u"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(c) => {
                    // Copy the full UTF-8 sequence.
                    let start = self.i;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.i += len;
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut integral = true;
        if self.peek() == Some(b'.') {
            integral = false;
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            integral = false;
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        // Integer literals stay exact (wire ids/checksums must not be
        // pushed through f64); out-of-i64-range integers fall back to
        // the closest f64, like any lossy JSON reader.
        if integral {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c\nd"}], "e": {}}"#).unwrap();
        assert_eq!(v.get(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get(&["a"]).unwrap().as_arr().unwrap()[2]
                .get(&["b"])
                .unwrap()
                .as_str(),
            Some("c\nd")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "format": "hlo-text", "dtype": "f32",
          "variants": {
            "conv3x3_c8h16w16k8n": {
              "kind": "conv_layer", "file": "conv3x3_c8h16w16k8n.hlo.txt",
              "inputs": [[8,16,16],[8,8,3,3],[8]], "output": [8,14,14],
              "c": 8, "h": 16, "w": 16, "k": 8, "relu": false, "pool": false,
              "macs": 112896, "psums": 12544
            }
          }
        }"#;
        let v = Json::parse(text).unwrap();
        let variant = v.get(&["variants", "conv3x3_c8h16w16k8n"]).unwrap();
        assert_eq!(variant.get(&["k"]).unwrap().as_usize(), Some(8));
        assert_eq!(variant.get(&["relu"]).unwrap().as_bool(), Some(false));
        assert_eq!(
            variant.get(&["output"]).unwrap().as_arr().unwrap()[1].as_usize(),
            Some(14)
        );
    }

    #[test]
    fn emitter_round_trips() {
        let cases = [
            r#"{"a":[1,2,{"b":"c"}],"e":{},"f":null,"g":true,"h":-1.5}"#,
            r#"[1,2,3]"#,
            r#""with \"quotes\" and \n newline""#,
        ];
        for text in cases {
            let v = Json::parse(text).unwrap();
            let emitted = v.to_json();
            assert_eq!(Json::parse(&emitted).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn emitter_integers_stay_integers() {
        assert_eq!(Json::num(42u32).to_json(), "42");
        assert_eq!(Json::num(-7i32).to_json(), "-7");
        assert_eq!(Json::num(1.5f64).to_json(), "1.5");
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("id", Json::num(3u32)),
            ("xs", Json::arr_i64([1, -2])),
            ("name", Json::str("hi")),
        ]);
        assert_eq!(v.to_json(), r#"{"id":3,"name":"hi","xs":[1,-2]}"#);
    }

    #[test]
    fn integers_above_2_pow_53_round_trip_exactly() {
        // f64 cannot represent odd integers above 2^53; the old
        // Num(f64)-only pipeline silently corrupted them. Ids and
        // checksums cross the wire through this path.
        let big: u64 = (1 << 60) + 3;
        let v = Json::uint(big);
        assert_eq!(v.to_json(), big.to_string());
        let back = Json::parse(&v.to_json()).unwrap();
        assert_eq!(back.as_u64(), Some(big));
        assert_ne!(big as f64 as u64, big, "test premise: f64 is lossy here");
    }

    #[test]
    fn exact_accessors_reject_lossy_nums() {
        assert_eq!(Json::Num(42.0).as_i64(), Some(42));
        assert_eq!(Json::Num(1.5).as_i64(), None);
        // A Num already above the exact window is refused rather than
        // silently rounded.
        assert_eq!(Json::Num(1e18).as_i64(), None);
        assert_eq!(Json::Int(-3).as_u64(), None, "negative is not a u64");
        assert_eq!(Json::Int(i64::MAX).as_i64(), Some(i64::MAX));
    }

    #[test]
    fn int_and_num_compare_numerically() {
        assert_eq!(Json::Int(42), Json::Num(42.0));
        assert_eq!(Json::parse("42").unwrap(), Json::num(42u32));
        assert_ne!(Json::Int(42), Json::Num(42.5));
        // Above the f64-exact window the cross-variant arm refuses the
        // comparison: 2^53+1 rounds to 2^53 as f64 but is NOT equal.
        let above = (1i64 << 53) + 1;
        assert_ne!(Json::Int(above), Json::Num(above as f64));
        assert_eq!(Json::Int(1i64 << 53), Json::Num((1i64 << 53) as f64));
        let a = Json::parse(r#"{"id":7}"#).unwrap();
        let b = Json::obj(vec![("id", Json::num(7u32))]);
        assert_eq!(a, b);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"a\\u0041b\"").unwrap(),
            Json::Str("aAb".into())
        );
    }
}
