//! Fleet scaling demo: throughput of one remote-core pool as TCP-served
//! peers join it.
//!
//! The paper scales by replicating its IP core on one board (0.224
//! GOPS/core, 4.48 GOPS at 20 cores). This example scales past the
//! board: N in-process `TcpServer` peers — each simulating a small
//! board — are fronted by a single pool of `RemoteBackend` workers
//! speaking wire protocol v3 (binary tensor frames, pipelined batch
//! submission), and the same mixed trace is pushed through fleets of
//! growing size. The run *asserts* the headline: throughput must
//! strictly increase 1 → 2 → 4 peers, or the exit code is nonzero.
//!
//! ```bash
//! cargo run --release --example fleet_scaling -- [--requests N] [--peer-cores N] [--samples N]
//! ```

use repro::coordinator::tcp::TcpServer;
use repro::coordinator::{CoordinatorConfig, Server};
use repro::model::trace::{generate, TraceConfig};
use repro::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[]).map_err(|e| anyhow::anyhow!(e))?;
    let requests = args.get_usize("requests", 96).map_err(|e| anyhow::anyhow!(e))?;
    let peer_cores = args.get_usize("peer-cores", 2).map_err(|e| anyhow::anyhow!(e))?;
    let samples = args.get_usize("samples", 3).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(
        (1..=20).contains(&peer_cores),
        "--peer-cores must be 1..=20 (each peer simulates a small board)"
    );
    anyhow::ensure!(samples >= 1, "--samples must be at least 1");

    let trace = generate(&TraceConfig {
        n: requests,
        mean_gap_us: 0,
        s52_fraction: 0.1,
        depthwise_fraction: 0.2,
        seed: 23,
    });

    println!(
        "fleet scaling: {requests}-request mixed trace (10% S52, 20% depthwise), \
         peers of {peer_cores} simulated cores each\n"
    );
    println!(
        "{:>6} {:>12} {:>14} {:>9} {:>9}  mix",
        "peers", "host_rps", "sim_gops_psum", "p50_us", "p99_us"
    );

    let mut rps_by_fleet: Vec<(usize, f64)> = Vec::new();
    for n_peers in [1usize, 2, 4] {
        let peers: Vec<TcpServer> = (0..n_peers)
            .map(|_| {
                TcpServer::start(
                    "127.0.0.1:0",
                    CoordinatorConfig::default().with_cores(peer_cores),
                )
                .expect("spawn fleet peer")
            })
            .collect();
        let config = CoordinatorConfig {
            n_cores: 0, // the front is pure fan-out: remote workers only
            ..CoordinatorConfig::default()
                .with_remote_peers(peers.iter().map(|p| p.addr.to_string()).collect())
        };
        let mut front = Server::try_new(config)?;
        // Best-of-N sampling: the peers share this host's CPU, so any
        // one run is hostage to scheduler noise. The max over a few
        // runs tracks the fleet's actual capacity — which scales with
        // peer count — while a regression to serial round trips
        // flattens every sample alike.
        let mut report = front.run_trace(&trace);
        anyhow::ensure!(
            report.n_errors == 0,
            "{n_peers}-peer fleet had {} job errors",
            report.n_errors
        );
        for _ in 1..samples {
            let rerun = front.run_trace(&trace);
            anyhow::ensure!(
                rerun.n_errors == 0,
                "{n_peers}-peer fleet had {} job errors",
                rerun.n_errors
            );
            if rerun.host_rps > report.host_rps {
                report = rerun;
            }
        }
        let mix = report
            .backend_mix
            .iter()
            .map(|(name, n)| format!("{name}x{n}"))
            .collect::<Vec<_>>()
            .join(",");
        println!(
            "{:>6} {:>12.1} {:>14.4} {:>9} {:>9}  [{mix}]",
            n_peers, report.host_rps, report.sim_gops_psum, report.p50_us, report.p99_us
        );
        rps_by_fleet.push((n_peers, report.host_rps));
        front.shutdown();
        for p in peers {
            p.stop();
        }
    }

    // The scaling contract itself: each doubling of the fleet must beat
    // the previous throughput outright (best-of-`samples` per size, so
    // a noisy shared runner doesn't flake the gate). Pipelined v3
    // transport keeps every peer's workers busy, so this holds with
    // headroom; a regression to serial round trips flattens the curve
    // and fails here.
    for pair in rps_by_fleet.windows(2) {
        let ((n_prev, rps_prev), (n_cur, rps_cur)) = (pair[0], pair[1]);
        anyhow::ensure!(
            rps_cur > rps_prev,
            "throughput did not scale: {n_prev} peers -> {rps_prev:.1} rps, \
             {n_cur} peers -> {rps_cur:.1} rps"
        );
    }
    println!("\nthroughput strictly increased with fleet size: OK");

    println!(
        "\nEvery request crossed a real socket: binary tensor frames out, \
         binary output tensors back, bit-exact numerics enforced by the \
         same parity harness that covers local backends."
    );
    Ok(())
}
