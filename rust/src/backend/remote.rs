//! [`ConvBackend`] over a persistent TCP connection to a wire-protocol
//! v4 peer ([`crate::coordinator::tcp`]) — the remote-core backend that
//! turns N TCP-served machines into one heterogeneous pool.
//!
//! The paper scales by replicating its IP core on one board; this
//! backend scales past the board: each [`RemoteBackend`] dials one
//! `TcpServer` peer, reads its `hello` capability advertisement (which
//! kinds it serves, in which accumulator mode, behind how many
//! workers), and then presents the whole remote machine to the local
//! pool as one more capability-masked, cost-weighted worker — exactly
//! the host-side scheduler shape the FPGA-CNN survey literature
//! prescribes for multi-accelerator deployments.
//!
//! **Framing negotiation:** a peer whose hello carries `"bin":true`
//! gets length-prefixed binary tensor frames both ways (the v3 fast
//! path — no per-element JSON on the `full_output` hot path); a legacy
//! peer (proto 2, no flag) transparently gets the old JSON-array
//! tensors. Outputs are bit-identical either way, so the parity
//! contract holds end-to-end over the wire for standard, depthwise and
//! pointwise-as-3×3 jobs (`rust/tests/backend_parity.rs` runs it as
//! just another backend, in both modes).
//!
//! **Weight caching (v4):** a peer whose hello carries `"wcache":true`
//! fronts a content-addressed weight store, so this backend claims
//! every blob's FNV-1a hash in the request header and, once a blob is
//! believed resident, stops shipping the bytes at all. The residency
//! belief lives in a [`KnownWeights`] set shared with the dispatcher
//! (which discounts the wire cost term for believed-resident jobs).
//! Frames on one connection are processed in order server-side and the
//! store admits a blob at parse time, so the belief is marked at *ship*
//! time: the first job of a batch carries the bytes, every later job of
//! the same model goes hash-only. If the belief is stale — the peer
//! evicted the blob under BRAM pressure — the peer answers a
//! `need_weights` frame and the backend re-ships inline exactly once on
//! the same request id; a second demand for the same job is a protocol
//! error. Every redial [`KnownWeights::clear`]s the set: a restarted
//! peer holds nothing, so the first job per blob re-ships and the cache
//! re-warms. Non-wcache peers (v2/v3) get inline tensors always.
//!
//! **Pipelining:** [`ConvBackend::run_batch`] writes a whole same-shape
//! batch in buffered bursts and reads the replies asynchronously —
//! up to [`REMOTE_PIPELINE_WINDOW`] jobs in flight, id-matched, reply
//! order free. That keeps every worker behind the peer busy instead of
//! round-tripping one job per RTT, which is what lets
//! [`CostModel::Remote`] honestly divide its compute quote by the
//! peer's advertised worker width. `run` (single job) remains the
//! strict request/reply special case.
//!
//! **Trace propagation (telemetry):** a peer whose hello carries
//! `"trace":true` accepts a `trace` id on request headers and answers
//! traced jobs with its server-side `queue_us`/`compute_us`, which this
//! backend folds — together with its own measured round trip — into
//! [`BackendRun::wire`] so the dispatcher can decompose wire time vs
//! remote compute per hop. Peers without the flag (every v2/v3 peer)
//! never see a trace field and their replies leave `wire` empty.
//!
//! Failure semantics: a dropped peer **fails its unanswered in-flight
//! jobs and drops the connection**; the next job redials (re-running
//! the handshake), and the pool's failover retry re-enqueues failed
//! jobs on capable siblings. A *clean* per-job error frame fails only
//! that job and keeps the connection. The `weights_resident` DMA
//! discount does not cross the wire: every remote job pays its own
//! transfer.
//!
//! **Health:** each backend runs a background probe thread
//! ([`HEALTH_PROBE_INTERVAL`]) that re-dials the peer on its own
//! short-lived connection, checks the fresh `hello` is no narrower than
//! the pool's routing snapshot, and — when the peer advertises the
//! `ping` feature in its hello — round-trips a `ping` control frame.
//! Because the probe never shares the job connection, it coexists with
//! any number of in-flight pipelined frames by construction. The
//! result lands in a shared [`WorkerHealth`] flag the dispatcher
//! reads: a dead peer is routed *around* while healthy siblings exist
//! (degraded capacity, not lost correctness), and a revived peer
//! rejoins routing as soon as one probe succeeds — no job has to fail
//! to discover it came back.

use super::{
    BackendRun, Capability, ConvBackend, CostModel, JobKind, JobPayload, KnownWeights,
    RemotePeerClass, WireTiming, WorkerHealth,
};
use crate::coordinator::request::fnv1a_bytes;
use crate::coordinator::tcp::{
    decode_i32_le, encode_request_frame_v4, read_line_capped, LineRead, MAX_BIN_BYTES,
    MAX_LINE_BYTES, PROTO_V2, PROTO_VERSION,
};
use crate::hw::ip_core::CycleStats;
use crate::hw::AccumMode;
use crate::model::{Tensor, QUICKSTART};
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard ceiling on waiting for one reply. A peer that stalls past this
/// fails the job (and the connection) instead of hanging a pool worker
/// forever; simulated jobs answer in milliseconds, so thirty seconds
/// only ever trips on a genuinely wedged peer. Writes carry the same
/// bound, so a peer that stops reading can't park a worker either.
pub const REMOTE_REPLY_TIMEOUT: Duration = Duration::from_secs(30);

/// Ceiling on (re)dialling a peer. A black-holed peer (powered off,
/// packets dropped without RST) must fail each redialling job after
/// seconds, not stall the pool worker for the kernel's multi-minute
/// default connect timeout.
pub const REMOTE_CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// How often the background health probe re-validates the peer
/// ([`RemoteBackend::connect`] uses this; tests and the chaos harness
/// shorten it via [`RemoteBackend::connect_with_probe`]).
pub const HEALTH_PROBE_INTERVAL: Duration = Duration::from_millis(250);

/// Client-side pipelining window: how many batch jobs this backend
/// keeps in flight on one connection before waiting for a reply.
/// Deliberately below the server's per-connection cap
/// ([`crate::coordinator::tcp::MAX_CONN_INFLIGHT`], 64) so a
/// well-behaved client never feels the server stop reading its socket.
pub const REMOTE_PIPELINE_WINDOW: usize = 16;

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

/// What the peer's `hello` advertised.
#[derive(Clone, Copy, Debug)]
struct PeerInfo {
    standard: bool,
    depthwise: bool,
    pointwise: bool,
    /// All I32-capable workers behind the peer (the capability gate).
    workers: u64,
    /// The fastest compute tier among those workers — what
    /// [`CostModel::Remote`] prices the peer's compute as.
    class: RemotePeerClass,
    /// Peer advertised the `ping` control frame in its hello (feature
    /// negotiation — plain v2 peers lack the flag and are never pinged).
    ping: bool,
    /// Peer advertised binary tensor framing (`"bin":true` in the
    /// hello). Off → this backend stays on v2 JSON tensors.
    bin: bool,
    /// Peer advertised a content-addressed weight store (`"wcache":true`
    /// in the hello). Off → every job ships its weights inline and no
    /// hash is ever claimed.
    wcache: bool,
    /// Peer advertised trace propagation (`"trace":true` in the hello):
    /// it accepts a `trace` id on request headers and answers traced
    /// jobs with server-side `queue_us`/`compute_us` timing. Off (every
    /// v2/v3 peer) → no trace field ever crosses this connection.
    trace: bool,
}

/// The capability flags routing snapshotted at construction; the probe
/// treats a peer that comes back narrower than this as unhealthy.
#[derive(Clone, Copy)]
struct CapSnapshot {
    standard: bool,
    depthwise: bool,
    pointwise: bool,
}

impl CapSnapshot {
    fn covered_by(&self, fresh: &PeerInfo) -> bool {
        (!self.standard || fresh.standard)
            && (!self.depthwise || fresh.depthwise)
            && (!self.pointwise || fresh.pointwise)
    }
}

/// One remote machine as a pool worker.
pub struct RemoteBackend {
    addr: String,
    /// Leaked once per constructed backend so worker names stay
    /// `&'static str` like every other backend's.
    name: &'static str,
    peer: PeerInfo,
    conn: Option<Conn>,
    next_id: u64,
    /// Shared with the dispatcher (via [`ConvBackend::health`]) and the
    /// probe thread.
    health: Arc<WorkerHealth>,
    /// Which weight blobs the peer's store is believed to hold (wire
    /// v4); shared with the dispatcher via
    /// [`ConvBackend::known_weights`], cleared on every redial.
    known: Arc<KnownWeights>,
    probe_stop: Arc<AtomicBool>,
    probe: Option<JoinHandle<()>>,
}

fn parse_hello(line: &str) -> Result<PeerInfo, String> {
    let j = Json::parse(line.trim()).map_err(|e| format!("malformed hello: {e}"))?;
    let h = j
        .get(&["hello"])
        .ok_or("first frame from peer is not a hello")?;
    let proto = h.get(&["proto"]).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    if !(PROTO_V2..=PROTO_VERSION).contains(&proto) {
        return Err(format!(
            "peer speaks wire protocol {proto}, this backend needs {PROTO_V2}..={PROTO_VERSION}"
        ));
    }
    let workers = h
        .get(&["workers"])
        .and_then(Json::as_arr)
        .ok_or("hello.workers missing")?;
    let mut info = PeerInfo {
        standard: false,
        depthwise: false,
        pointwise: false,
        workers: 0,
        class: RemotePeerClass::HostMacs,
        // Feature negotiation rides on the hello: peers that can answer
        // `ping` control frames say so; plain v2 peers simply lack the
        // flag and are never sent one. Same for binary tensor framing.
        ping: h.get(&["ping"]).and_then(Json::as_bool).unwrap_or(false),
        bin: h.get(&["bin"]).and_then(Json::as_bool).unwrap_or(false),
        wcache: h.get(&["wcache"]).and_then(Json::as_bool).unwrap_or(false),
        trace: h.get(&["trace"]).and_then(Json::as_bool).unwrap_or(false),
    };
    let mut classes: Vec<RemotePeerClass> = Vec::new();
    for w in workers {
        // The wire serves I32 production traffic only; wrap-8 silicon
        // on the peer can never answer us, so it doesn't count.
        if w.get(&["accum"]).and_then(Json::as_str) != Some("i32") {
            continue;
        }
        info.workers += 1;
        let flag = |k: &str| w.get(&[k]).and_then(Json::as_bool).unwrap_or(false);
        info.standard |= flag("standard");
        info.depthwise |= flag("depthwise");
        info.pointwise |= flag("pointwise");
        // Missing `model` tags price conservatively (host loops).
        classes.push(
            w.get(&["model"])
                .and_then(Json::as_str)
                .map(RemotePeerClass::from_tag)
                .unwrap_or(RemotePeerClass::HostMacs),
        );
    }
    if info.workers == 0 {
        return Err("peer advertises no i32-capable workers".into());
    }
    // Price the peer by its fastest advertised tier (cheapest local
    // reference-job quote).
    info.class = classes
        .into_iter()
        .min_by_key(|c| c.model().cost(&QUICKSTART, JobKind::Standard))
        .expect("workers > 0 implies at least one class");
    Ok(info)
}

fn dial(addr: &str) -> anyhow::Result<(Conn, PeerInfo)> {
    // Try every resolved address (std's connect semantics): dual-stack
    // hostnames must not fail just because the first family is dead.
    let mut last_err: Option<std::io::Error> = None;
    let mut stream: Option<TcpStream> = None;
    for sock_addr in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sock_addr, REMOTE_CONNECT_TIMEOUT) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(e) => last_err = Some(e),
        }
    }
    let stream = match stream {
        Some(s) => s,
        None => match last_err {
            Some(e) => return Err(anyhow::anyhow!("{addr}: connect failed: {e}")),
            None => return Err(anyhow::anyhow!("{addr}: resolved to no address")),
        },
    };
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(REMOTE_REPLY_TIMEOUT))?;
    stream.set_write_timeout(Some(REMOTE_REPLY_TIMEOUT))?;
    let writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    match read_line_capped(&mut reader, &mut buf, MAX_LINE_BYTES)? {
        LineRead::Eof => anyhow::bail!("{addr}: peer closed before sending a hello"),
        LineRead::Line => {}
    }
    let line = String::from_utf8_lossy(&buf);
    let peer = parse_hello(&line).map_err(|e| anyhow::anyhow!("{addr}: {e}"))?;
    Ok((Conn { writer, reader }, peer))
}

/// Encode one job as a complete request frame in the negotiated
/// encoding: plain v2/v3 (no hash claimed), or — against a wcache peer
/// — a v4 frame that always claims the blob's content hash and omits
/// the weight payload entirely when `hash_only`. `trace` is the
/// propagated trace id (0 = untraced — the field is omitted); callers
/// must pass 0 unless the peer's hello advertised `"trace":true`.
fn job_frame(
    id: u64,
    job: &JobPayload,
    bin: bool,
    hash: Option<u64>,
    hash_only: bool,
    trace: u64,
) -> Vec<u8> {
    encode_request_frame_v4(
        id,
        job.kind,
        job.spec,
        job.img.data(),
        (hash.is_none() || !hash_only).then(|| job.weights.data()),
        hash,
        job.bias,
        true, // full_output: the backend must reconstruct the tensor
        bin,
        trace,
    )
}

/// One pipelined in-flight job: its index in the caller's slice plus
/// the weight-cache state of the frame last sent for it (wire v4).
struct Inflight {
    idx: usize,
    /// Content hash claimed in the request header (wcache peers only).
    hash: Option<u64>,
    /// The last frame omitted the weight payload.
    hash_only: bool,
    /// A `need_weights` re-ship already happened for this job.
    reshipped: bool,
    /// When the first frame for this job was written — the wire
    /// round-trip anchor for [`WireTiming::rtt_us`].
    sent: Instant,
}

fn expected_shape(job: &JobPayload) -> Vec<usize> {
    let (oh, ow) = (job.spec.conv_oh(), job.spec.conv_ow());
    match job.kind {
        JobKind::Depthwise => vec![job.spec.c, oh, ow],
        JobKind::Standard | JobKind::PointwiseAs3x3 => vec![job.spec.k, oh, ow],
    }
}

/// Read one complete reply frame off the connection: the JSON header
/// line plus, when it declares `bin_output`, the decoded i32 body.
/// The body is consumed *with* its header unconditionally — even a
/// frame the caller will discard as stale must not leave its bytes in
/// the stream, or every later header would desync.
fn read_reply_frame(conn: &mut Conn) -> anyhow::Result<(Json, Option<Vec<i32>>)> {
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match read_line_capped(&mut conn.reader, &mut buf, MAX_LINE_BYTES)? {
            LineRead::Eof => anyhow::bail!("peer closed the connection mid-request"),
            LineRead::Line => {}
        }
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let j = Json::parse(trimmed).map_err(|e| anyhow::anyhow!("unparseable reply: {e}"))?;
        let body = match j.get(&["bin_output"]).and_then(Json::as_u64) {
            None => None,
            Some(n) => {
                let n = usize::try_from(n)
                    .ok()
                    .filter(|&n| n <= MAX_BIN_BYTES)
                    .ok_or_else(|| anyhow::anyhow!("bin_output {n} exceeds the frame cap"))?;
                let mut body = vec![0u8; n];
                conn.reader.read_exact(&mut body)?;
                Some(decode_i32_le(&body))
            }
        };
        return Ok((j, body));
    }
}

/// Interpret one id-matched reply. The outer `Err` is a protocol
/// failure (caller must treat the stream as desynced and drop the
/// connection); the inner `Err(String)` is a *clean* job error the
/// peer answered on a healthy, still-aligned stream.
fn decode_reply(
    resp: &Json,
    body: Option<Vec<i32>>,
    job: &JobPayload,
    rtt_us: u64,
) -> anyhow::Result<Result<BackendRun, String>> {
    if resp.get(&["ok"]).and_then(Json::as_bool) != Some(true) {
        let msg = resp
            .get(&["error"])
            .and_then(Json::as_str)
            .unwrap_or("unspecified peer error");
        return Ok(Err(msg.to_string()));
    }
    let shape: Vec<usize> = resp
        .get(&["shape"])
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("reply missing shape (peer ignored full_output)"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape element")))
        .collect::<Result<_, _>>()?;
    let data: Vec<i32> = match body {
        // Binary body: already decoded i32-LE words.
        Some(words) => words,
        // JSON tensor reply (v2 peers, or non-bin requests).
        None => resp
            .get(&["output"])
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("reply missing output (peer ignored full_output)"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|n| n as i32)
                    .ok_or_else(|| anyhow::anyhow!("bad output element"))
            })
            .collect::<Result<_, _>>()?,
    };
    let want = expected_shape(job);
    anyhow::ensure!(
        shape == want,
        "peer output shape {shape:?} != expected {want:?}"
    );
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "peer output length {} != shape {shape:?}",
        data.len()
    );
    let compute = resp
        .get(&["compute_cycles"])
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as u64;
    let total = resp
        .get(&["total_cycles"])
        .and_then(Json::as_f64)
        .unwrap_or(compute as f64) as u64;
    // Traced peers decompose the round trip: the reply carries the
    // server-side queue residency and compute wall time, so the caller
    // can split `rtt_us` into wire vs remote work. Untraced replies
    // (v2/v3 peers, or tracing off) leave `wire` empty and the
    // dispatcher falls back to whole-hop accounting.
    let wire = resp
        .get(&["compute_us"])
        .and_then(Json::as_u64)
        .map(|peer_compute_us| WireTiming {
            rtt_us,
            peer_queue_us: resp.get(&["queue_us"]).and_then(Json::as_u64).unwrap_or(0),
            peer_compute_us,
        });
    Ok(Ok(BackendRun {
        output: Tensor::from_vec(&shape, data),
        cycles: CycleStats {
            compute,
            total,
            ..Default::default()
        },
        wire,
    }))
}

/// One health probe: fresh dial, hello validation against the routing
/// snapshot, and — when the peer negotiated it — a `ping` round trip.
/// Runs on its own short-lived connection so it never desyncs the job
/// stream, however many pipelined frames are in flight there.
fn probe_once(addr: &str, snapshot: CapSnapshot) -> bool {
    let Ok((mut conn, fresh)) = dial(addr) else {
        return false;
    };
    if !snapshot.covered_by(&fresh) {
        // The peer restarted narrower than the pool's routing snapshot:
        // jobs routed by the old mask would bounce — treat as down.
        return false;
    }
    if !fresh.ping {
        // Plain v2 peer: the hello round trip itself is the probe.
        return true;
    }
    if writeln!(conn.writer, "{}", Json::obj(vec![("ping", Json::num(1.0))]).to_json()).is_err() {
        return false;
    }
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match read_line_capped(&mut conn.reader, &mut buf, MAX_LINE_BYTES) {
            Ok(LineRead::Line) => {}
            _ => return false,
        }
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(trimmed) else {
            return false;
        };
        if j.get(&["hello"]).is_some() {
            continue; // stray greeting; keep draining
        }
        return j.get(&["pong"]).and_then(Json::as_f64).is_some();
    }
}

fn spawn_probe(
    addr: String,
    snapshot: CapSnapshot,
    health: Arc<WorkerHealth>,
    stop: Arc<AtomicBool>,
    interval: Duration,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("probe-{addr}"))
        .spawn(move || {
            // Sleep in short ticks so Drop never waits a full interval
            // to join this thread.
            let tick = Duration::from_millis(25).min(interval).max(Duration::from_millis(1));
            let mut slept = Duration::ZERO;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(tick);
                slept += tick;
                if slept < interval {
                    continue;
                }
                slept = Duration::ZERO;
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                health.set_healthy(probe_once(&addr, snapshot));
            }
        })
        .expect("spawn remote health probe")
}

impl RemoteBackend {
    /// Dial `addr` (`host:port`) and perform the handshake. Errors when
    /// the peer is unreachable, greets with anything but a valid v2/v3
    /// `hello`, or fronts no I32-capable workers.
    pub fn connect(addr: &str) -> anyhow::Result<Self> {
        Self::connect_with_probe(addr, HEALTH_PROBE_INTERVAL)
    }

    /// [`Self::connect`] with an explicit health-probe interval (the
    /// chaos harness and tests shorten it to observe flaps quickly).
    pub fn connect_with_probe(addr: &str, probe_interval: Duration) -> anyhow::Result<Self> {
        let (conn, peer) = dial(addr)?;
        let name: &'static str = Box::leak(format!("remote@{addr}").into_boxed_str());
        let health = WorkerHealth::new();
        let probe_stop = Arc::new(AtomicBool::new(false));
        let snapshot = CapSnapshot {
            standard: peer.standard,
            depthwise: peer.depthwise,
            pointwise: peer.pointwise,
        };
        let probe = spawn_probe(
            addr.to_string(),
            snapshot,
            Arc::clone(&health),
            Arc::clone(&probe_stop),
            probe_interval,
        );
        Ok(RemoteBackend {
            addr: addr.to_string(),
            name,
            peer,
            conn: Some(conn),
            next_id: 1,
            health,
            known: KnownWeights::new(),
            probe_stop,
            probe: Some(probe),
        })
    }

    /// The shared liveness flag (what [`ConvBackend::health`] exposes
    /// to the pool); public for harnesses that poll recovery.
    pub fn health_flag(&self) -> Arc<WorkerHealth> {
        Arc::clone(&self.health)
    }

    /// The peer address this backend fronts.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// I32-capable workers the peer advertised in its `hello`.
    pub fn peer_workers(&self) -> u64 {
        self.peer.workers
    }

    /// Whether the peer negotiated binary tensor framing (`"bin":true`
    /// in its hello). Observability for mixed-protocol fleets.
    pub fn peer_binary(&self) -> bool {
        self.peer.bin
    }

    /// Whether the peer negotiated the content-addressed weight store
    /// (`"wcache":true` in its hello). Off for v2/v3 peers: every job
    /// ships weights inline and no hash is ever claimed.
    pub fn peer_wcache(&self) -> bool {
        self.peer.wcache
    }

    /// Whether the peer negotiated trace propagation (`"trace":true` in
    /// its hello): traced jobs carry their id on the wire and the peer
    /// answers with server-side `queue_us`/`compute_us`. Off for v2/v3
    /// peers — no trace field ever crosses such a connection.
    pub fn peer_trace(&self) -> bool {
        self.peer.trace
    }

    /// Send-time cache decision for one job against a wcache peer:
    /// `(hash, hash_only)`. Marks the belief *at ship time* — the store
    /// admits a blob when it parses the frame and frames on one
    /// connection are processed in order, so later jobs in the same
    /// burst can already go hash-only.
    fn plan_weights(&self, job: &JobPayload) -> (Option<u64>, bool) {
        if !self.peer.wcache {
            return (None, false);
        }
        let h = fnv1a_bytes(job.weights.data());
        if self.known.contains(h) {
            (Some(h), true)
        } else {
            self.known.record_miss();
            self.known.mark_known(h);
            (Some(h), false)
        }
    }

    /// Make sure a live connection exists, redialling after an earlier
    /// failure. The fresh handshake re-verifies the peer still speaks a
    /// known protocol revision; the pool snapshotted this worker's
    /// capability at spawn, so a peer that comes back *narrower* can't
    /// be served honestly any more — fail loudly (every job errors with
    /// this message) instead of letting jobs silently bounce off the
    /// peer's own mask.
    fn ensure_conn(&mut self) -> anyhow::Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let (conn, fresh) = match dial(&self.addr) {
            Ok(ok) => ok,
            Err(e) => {
                self.health.set_healthy(false);
                return Err(e);
            }
        };
        if !((!self.peer.standard || fresh.standard)
            && (!self.peer.depthwise || fresh.depthwise)
            && (!self.peer.pointwise || fresh.pointwise))
        {
            self.health.set_healthy(false);
            anyhow::bail!(
                "remote {}: peer restarted with a narrower capability than \
                 this pool's routing snapshot; rebuild the pool",
                self.addr
            );
        }
        // A fresh connection may front a restarted peer whose weight
        // store is empty: drop every residency belief so the next job
        // per blob re-ships inline (and the cache re-warms from there).
        self.known.clear();
        self.peer = fresh;
        self.conn = Some(conn);
        Ok(())
    }

    /// One request/reply exchange. The outer `Err` is a transport or
    /// protocol failure (stream desynced or dead — caller must drop the
    /// connection); the inner `Err(String)` is a *clean* job error the
    /// peer answered on a healthy, still-aligned stream (the connection
    /// stays up).
    fn round_trip(
        &mut self,
        id: u64,
        job: &JobPayload,
    ) -> anyhow::Result<Result<BackendRun, String>> {
        let bin = self.peer.bin;
        let trace = if self.peer.trace { job.trace_id } else { 0 };
        let (hash, mut hash_only) = self.plan_weights(job);
        let mut reshipped = false;
        let conn = self.conn.as_mut().expect("connection ensured by run()");
        let sent = Instant::now();
        conn.writer.write_all(&job_frame(id, job, bin, hash, hash_only, trace))?;
        loop {
            let (resp, body) = read_reply_frame(conn)?;
            if resp.get(&["hello"]).is_some() || resp.get(&["pong"]).is_some() {
                continue; // stray control frame; keep draining
            }
            match resp.get(&["id"]).and_then(Json::as_u64) {
                Some(rid) if rid == id => {
                    if resp.get(&["need_weights"]).and_then(Json::as_bool) == Some(true) {
                        // The residency belief was stale (the peer
                        // evicted the blob): re-ship inline exactly once
                        // on the same id. A demand for weights the last
                        // frame already carried means the stream is not
                        // to be trusted.
                        let h = hash.ok_or_else(|| {
                            anyhow::anyhow!("peer demanded weights on a non-caching connection")
                        })?;
                        anyhow::ensure!(
                            hash_only && !reshipped,
                            "peer demanded weights it was just sent inline"
                        );
                        self.known.forget(h);
                        self.known.record_miss();
                        self.known.mark_known(h);
                        hash_only = false;
                        reshipped = true;
                        conn.writer.write_all(&job_frame(id, job, bin, hash, false, trace))?;
                        continue;
                    }
                    let rtt_us = sent.elapsed().as_micros() as u64;
                    let out = decode_reply(&resp, body, job, rtt_us)?;
                    if out.is_ok() && hash_only {
                        self.known.record_hit(job.weights.data().len() as u64);
                    }
                    return Ok(out);
                }
                // A stale reply to an older request this backend already
                // failed: its body was consumed with its header, so
                // draining it realigns the stream.
                Some(_) => continue,
                None => anyhow::bail!("reply frame without an id"),
            }
        }
    }
}

impl ConvBackend for RemoteBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn capability(&self) -> Capability {
        Capability {
            standard3x3: self.peer.standard,
            depthwise: self.peer.depthwise,
            pointwise_as_3x3: self.peer.pointwise,
            accum: AccumMode::I32,
            // The wire rejects standard/pointwise specs violating §4.1
            // regardless of the peer's pool; the mask must mirror
            // that, or jobs a local host worker could serve get routed
            // here only to come back as peer errors.
            paper_specs_only: true,
            spec_allowlist: None,
        }
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Remote {
            // run_batch keeps up to a window of jobs in flight, so the
            // peer's advertised worker width genuinely parallelises our
            // submissions — the quote divides compute by it (the wire
            // term stays single-stream; see CostModel::cost).
            workers: self.peer.workers.max(1),
            class: self.peer.class,
        }
    }

    fn health(&self) -> Option<Arc<WorkerHealth>> {
        Some(Arc::clone(&self.health))
    }

    fn known_weights(&self) -> Option<Arc<KnownWeights>> {
        // Exposed even against v2/v3 peers: the set just stays empty
        // there (plan_weights never touches it), so the dispatcher's
        // discount is a no-op and the report shows zero cache traffic.
        Some(Arc::clone(&self.known))
    }

    fn run(&mut self, job: &JobPayload) -> anyhow::Result<BackendRun> {
        job.validate()?;
        self.ensure_conn()?;
        let id = self.next_id;
        self.next_id += 1;
        match self.round_trip(id, job) {
            Ok(Ok(run)) => {
                self.health.set_healthy(true);
                Ok(run)
            }
            // A clean job-error frame arrived on an aligned stream: the
            // job fails but the connection is healthy — no redial churn,
            // and no health flap either.
            Ok(Err(job_err)) => Err(anyhow::anyhow!(
                "remote {}: peer answered with a job error: {job_err}",
                self.addr
            )),
            Err(e) => {
                // Transport/protocol failure: fail this in-flight job
                // and drop the connection; the next job redials instead
                // of reusing a wedged or desynced stream. Mark the peer
                // unhealthy right away so the dispatcher routes around
                // it without waiting for the next probe tick.
                self.conn = None;
                self.health.set_healthy(false);
                Err(anyhow::anyhow!("remote {}: {e}", self.addr))
            }
        }
    }

    /// Pipelined batch submission: write up to [`REMOTE_PIPELINE_WINDOW`]
    /// request frames in one buffered burst, then keep the window full
    /// — read one id-matched reply, write the next frame — until every
    /// job is answered. A transport/protocol failure fails every job
    /// not yet answered (the pool's failover re-enqueues them) and
    /// drops the connection; clean per-job error frames fail only their
    /// job.
    fn run_batch(&mut self, jobs: &[JobPayload]) -> Vec<anyhow::Result<BackendRun>> {
        let mut results: Vec<Option<anyhow::Result<BackendRun>>> =
            jobs.iter().map(|_| None).collect();
        // Shape errors are local, before anything touches the wire.
        for (i, job) in jobs.iter().enumerate() {
            if let Err(e) = job.validate() {
                results[i] = Some(Err(e));
            }
        }
        let order: Vec<usize> = (0..jobs.len()).filter(|&i| results[i].is_none()).collect();
        if order.is_empty() {
            return results.into_iter().map(|r| r.expect("all filled")).collect();
        }
        if let Err(e) = self.ensure_conn() {
            let msg = e.to_string();
            for i in order {
                results[i] = Some(Err(anyhow::anyhow!("remote {}: {msg}", self.addr)));
            }
            return results.into_iter().map(|r| r.expect("all filled")).collect();
        }
        let bin = self.peer.bin;
        // Take the connection so the borrow checker lets us allocate
        // ids while writing; restored below unless the stream died.
        let mut conn = self.conn.take().expect("ensured above");
        let mut inflight: HashMap<u64, Inflight> = HashMap::new();
        let mut cursor = 0usize;
        let mut transport: Option<anyhow::Error> = None;
        // Opening burst: fill the window with one buffered write — the
        // whole batch head crosses the wire in a single syscall instead
        // of one write per RTT. plan_weights marks residency at ship
        // time, so a batch of same-model jobs carries its blob in the
        // first frame only — the rest of the burst is already hash-only.
        let mut burst: Vec<u8> = Vec::new();
        while cursor < order.len() && inflight.len() < REMOTE_PIPELINE_WINDOW {
            let idx = order[cursor];
            cursor += 1;
            let id = self.next_id;
            self.next_id += 1;
            let trace = if self.peer.trace { jobs[idx].trace_id } else { 0 };
            let (hash, hash_only) = self.plan_weights(&jobs[idx]);
            burst.extend_from_slice(&job_frame(id, &jobs[idx], bin, hash, hash_only, trace));
            inflight.insert(
                id,
                Inflight { idx, hash, hash_only, reshipped: false, sent: Instant::now() },
            );
        }
        if let Err(e) = conn.writer.write_all(&burst) {
            transport = Some(e.into());
        }
        drop(burst);
        while transport.is_none() && !inflight.is_empty() {
            let (resp, body) = match read_reply_frame(&mut conn) {
                Ok(frame) => frame,
                Err(e) => {
                    transport = Some(e);
                    break;
                }
            };
            if resp.get(&["hello"]).is_some() || resp.get(&["pong"]).is_some() {
                continue; // stray control frame; keep draining
            }
            let Some(rid) = resp.get(&["id"]).and_then(Json::as_u64) else {
                transport = Some(anyhow::anyhow!("reply frame without an id"));
                break;
            };
            let Some(fl) = inflight.remove(&rid) else {
                continue; // stale reply from a pre-batch failure; drained
            };
            if resp.get(&["need_weights"]).and_then(Json::as_bool) == Some(true) {
                // Stale residency belief: the peer evicted this blob
                // since we last shipped it. Re-ship inline exactly once
                // on the same id; a demand for weights the frame already
                // carried (or a second demand for the same job) means
                // the stream is not to be trusted.
                if !fl.hash_only || fl.reshipped {
                    inflight.insert(rid, fl);
                    transport =
                        Some(anyhow::anyhow!("peer demanded weights it was just sent inline"));
                    break;
                }
                let h = fl.hash.expect("hash_only implies a claimed hash");
                self.known.forget(h);
                self.known.record_miss();
                self.known.mark_known(h);
                let trace = if self.peer.trace { jobs[fl.idx].trace_id } else { 0 };
                let frame = job_frame(rid, &jobs[fl.idx], bin, fl.hash, false, trace);
                let fl = Inflight {
                    hash_only: false,
                    reshipped: true,
                    ..fl
                };
                if let Err(e) = conn.writer.write_all(&frame) {
                    inflight.insert(rid, fl);
                    transport = Some(e.into());
                    break;
                }
                inflight.insert(rid, fl);
                continue; // the job still occupies its slot; no top-up
            }
            match decode_reply(&resp, body, &jobs[fl.idx], fl.sent.elapsed().as_micros() as u64) {
                Ok(Ok(run)) => {
                    if fl.hash_only {
                        self.known
                            .record_hit(jobs[fl.idx].weights.data().len() as u64);
                    }
                    results[fl.idx] = Some(Ok(run));
                }
                Ok(Err(job_err)) => {
                    results[fl.idx] = Some(Err(anyhow::anyhow!(
                        "remote {}: peer answered with a job error: {job_err}",
                        self.addr
                    )))
                }
                Err(e) => {
                    // `rid` was already removed from `inflight`; put it
                    // back so the transport cleanup below fails this job
                    // too instead of leaving a hole that panics the
                    // final unwrap.
                    inflight.insert(rid, fl);
                    transport = Some(e);
                    break;
                }
            }
            // Keep the window full.
            if cursor < order.len() {
                let idx = order[cursor];
                cursor += 1;
                let id = self.next_id;
                self.next_id += 1;
                let trace = if self.peer.trace { jobs[idx].trace_id } else { 0 };
                let (hash, hash_only) = self.plan_weights(&jobs[idx]);
                let fl = Inflight { idx, hash, hash_only, reshipped: false, sent: Instant::now() };
                if let Err(e) =
                    conn.writer.write_all(&job_frame(id, &jobs[idx], bin, hash, hash_only, trace))
                {
                    inflight.insert(id, fl);
                    transport = Some(e.into());
                    break;
                }
                inflight.insert(id, fl);
            }
        }
        match transport {
            None => {
                self.conn = Some(conn);
                self.health.set_healthy(true);
            }
            Some(e) => {
                // Stream dead or desynced: fail everything unanswered
                // (in flight or never submitted) and force a redial.
                self.conn = None;
                self.health.set_healthy(false);
                let msg = e.to_string();
                for (_id, fl) in inflight {
                    results[fl.idx] = Some(Err(anyhow::anyhow!("remote {}: {msg}", self.addr)));
                }
                while cursor < order.len() {
                    results[order[cursor]] =
                        Some(Err(anyhow::anyhow!("remote {}: {msg}", self.addr)));
                    cursor += 1;
                }
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("every job answered or failed"))
            .collect()
    }
}

impl Drop for RemoteBackend {
    fn drop(&mut self) {
        self.probe_stop.store(true, Ordering::Relaxed);
        if let Some(probe) = self.probe.take() {
            let _ = probe.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::Batch;
    use crate::coordinator::config::CoordinatorConfig;
    use crate::coordinator::dispatch::CorePool;
    use crate::coordinator::request::{ConvJob, Submission};
    use crate::coordinator::tcp::TcpServer;
    use crate::hw::IpCoreConfig;
    use crate::model::{golden, LayerSpec};
    use crate::util::prng::Prng;
    use std::io::BufRead;
    use std::net::TcpListener;
    use std::sync::mpsc::channel;

    /// A valid *v2* greeting for hand-rolled fake peers: proto 2, no
    /// `bin` flag. Doubles as the legacy-interop fixture — a front
    /// parsing this must fall back to JSON tensors.
    fn hello_line() -> &'static str {
        r#"{"hello":{"proto":2,"freq_hz":112000000,"cores":1,"workers":[{"backend":"sim-ipcore-i32","standard":true,"depthwise":true,"pointwise":true,"accum":"i32","model":"sim-cycles","quote":6272}]}}"#
    }

    #[test]
    fn connect_rejects_malformed_hello() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(s, "this is not a hello").unwrap();
        });
        let err = RemoteBackend::connect(&addr).unwrap_err();
        assert!(err.to_string().contains("hello"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn connect_rejects_wrong_protocol_revision() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(
                s,
                r#"{{"hello":{{"proto":1,"workers":[{{"backend":"x","standard":true,"accum":"i32"}}]}}}}"#
            )
            .unwrap();
        });
        let err = RemoteBackend::connect(&addr).unwrap_err();
        assert!(err.to_string().contains("protocol"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn connect_rejects_peer_without_i32_workers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(
                s,
                r#"{{"hello":{{"proto":2,"workers":[{{"backend":"sim-ipcore-wrap8","standard":true,"depthwise":false,"pointwise":true,"accum":"wrap8","quote":6272}}]}}}}"#
            )
            .unwrap();
        });
        let err = RemoteBackend::connect(&addr).unwrap_err();
        assert!(err.to_string().contains("i32"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn mid_stream_disconnect_fails_the_job_then_reconnects() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            // Connection 1: greet, swallow one request, drop mid-stream.
            {
                let (mut s, _) = listener.accept().unwrap();
                writeln!(s, "{}", hello_line()).unwrap();
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
            }
            // Connection 2 (the reconnect): greet and answer properly.
            let (mut s, _) = listener.accept().unwrap();
            writeln!(s, "{}", hello_line()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let req = Json::parse(line.trim()).unwrap();
            // The v2 fixture negotiated no binary framing, so the
            // request must be pure JSON — one parseable line, no body.
            assert!(req.get(&["bin"]).is_none(), "v2 peer got a binary frame");
            let id = req.get(&["id"]).unwrap().as_u64().unwrap();
            // All-zero 1x3x3 -> k=4 job: the answer is four zero words.
            let reply = Json::obj(vec![
                ("id", Json::uint(id)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("shape", Json::arr_u64([4u64, 1, 1])),
                ("output", Json::arr_i64([0i64, 0, 0, 0])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
        });
        let mut be = RemoteBackend::connect(&addr).unwrap();
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        // Job 1 fails (dropped peer), job 2 succeeds over the redial.
        let err = be.run(&payload).unwrap_err();
        assert!(err.to_string().contains("remote"), "{err}");
        let run = be.run(&payload).unwrap();
        assert_eq!(run.output.shape(), &[4, 1, 1]);
        assert_eq!(run.output.data(), &[0, 0, 0, 0]);
        t.join().unwrap();
    }

    #[test]
    fn clean_peer_job_error_keeps_the_connection() {
        // The fake peer accepts exactly ONE connection: it errors job 1
        // cleanly, then serves job 2 on the same stream. If the client
        // wrongly redialled after the clean error, job 2 would have no
        // server to connect to and this test would fail.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            drop(listener); // no second accept possible
            writeln!(s, "{}", hello_line()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let id1 = Json::parse(line.trim()).unwrap().get(&["id"]).unwrap().as_u64().unwrap();
            let err = Json::obj(vec![
                ("id", Json::uint(id1)),
                ("ok", Json::Bool(false)),
                ("error", Json::str("boom")),
            ]);
            writeln!(s, "{}", err.to_json()).unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            let id2 = Json::parse(line.trim()).unwrap().get(&["id"]).unwrap().as_u64().unwrap();
            let reply = Json::obj(vec![
                ("id", Json::uint(id2)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("shape", Json::arr_u64([4u64, 1, 1])),
                ("output", Json::arr_i64([0i64, 0, 0, 0])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
        });
        let mut be = RemoteBackend::connect(&addr).unwrap();
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let err = be.run(&payload).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
        let run = be.run(&payload).expect("same connection serves the next job");
        assert_eq!(run.output.data(), &[0, 0, 0, 0]);
        t.join().unwrap();
    }

    /// A v4-ish greeting advertising trace propagation but neither
    /// binary framing nor the weight store: requests stay JSON, so a
    /// fake peer can assert on the exact header fields.
    fn traced_hello_line() -> &'static str {
        r#"{"hello":{"proto":4,"trace":true,"freq_hz":112000000,"cores":1,"workers":[{"backend":"sim-ipcore-i32","standard":true,"depthwise":true,"pointwise":true,"accum":"i32","model":"sim-cycles","quote":6272}]}}"#
    }

    #[test]
    fn v2_peer_never_sees_a_trace_field() {
        // Satellite negotiation contract, client side: a traced job
        // against a peer whose hello lacks the trace flag must
        // serialise WITHOUT the trace field, and its reply leaves the
        // wire decomposition empty.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(s, "{}", hello_line()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let req = Json::parse(line.trim()).unwrap();
            assert!(req.get(&["trace"]).is_none(), "v2 peer saw a trace field");
            let id = req.get(&["id"]).unwrap().as_u64().unwrap();
            let reply = Json::obj(vec![
                ("id", Json::uint(id)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("shape", Json::arr_u64([4u64, 1, 1])),
                ("output", Json::arr_i64([0i64, 0, 0, 0])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
        });
        let mut be = RemoteBackend::connect(&addr).unwrap();
        assert!(!be.peer_trace(), "a v2 hello must not negotiate tracing");
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 7,
        };
        let run = be.run(&payload).unwrap();
        assert!(run.wire.is_none(), "untraced peer reply must not claim wire timing");
        t.join().unwrap();
    }

    #[test]
    fn traced_peer_gets_the_id_and_replies_decompose_the_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(s, "{}", traced_hello_line()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            // Job 1 (traced): the header must carry the propagated id;
            // the reply decomposes server-side time.
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let req = Json::parse(line.trim()).unwrap();
            assert_eq!(req.get(&["trace"]).and_then(Json::as_u64), Some(7));
            let id = req.get(&["id"]).unwrap().as_u64().unwrap();
            let reply = Json::obj(vec![
                ("id", Json::uint(id)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("queue_us", Json::uint(11)),
                ("compute_us", Json::uint(23)),
                ("shape", Json::arr_u64([4u64, 1, 1])),
                ("output", Json::arr_i64([0i64, 0, 0, 0])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
            // Job 2 (untraced, same traced connection): no field.
            line.clear();
            r.read_line(&mut line).unwrap();
            let req = Json::parse(line.trim()).unwrap();
            assert!(req.get(&["trace"]).is_none(), "trace_id 0 must omit the field");
            let id = req.get(&["id"]).unwrap().as_u64().unwrap();
            let reply = Json::obj(vec![
                ("id", Json::uint(id)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("shape", Json::arr_u64([4u64, 1, 1])),
                ("output", Json::arr_i64([0i64, 0, 0, 0])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
        });
        let mut be = RemoteBackend::connect(&addr).unwrap();
        assert!(be.peer_trace(), "hello trace flag must negotiate on");
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let mut payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 7,
        };
        let run = be.run(&payload).unwrap();
        let w = run.wire.expect("traced reply decomposes the round trip");
        assert_eq!((w.peer_queue_us, w.peer_compute_us), (11, 23));
        assert_eq!(w.wire_us(), w.rtt_us.saturating_sub(34));
        payload.trace_id = 0;
        let run = be.run(&payload).unwrap();
        assert!(run.wire.is_none(), "untraced job gets whole-hop accounting");
        t.join().unwrap();
    }

    #[test]
    fn capability_and_cost_reflect_the_peer_hello() {
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_golden_workers(1),
        )
        .unwrap();
        let be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        let cap = be.capability();
        assert!(cap.standard3x3 && cap.depthwise && cap.pointwise_as_3x3);
        assert_eq!(cap.accum, AccumMode::I32);
        assert!(cap.paper_specs_only, "the wire applies the §4.1 gate");
        assert_eq!(be.peer_workers(), 2);
        assert!(be.peer_binary(), "a v4 server negotiates binary frames");
        assert!(be.peer_wcache(), "a v4 server negotiates the weight store");
        assert!(be.peer_trace(), "a v4 server negotiates trace propagation");
        // Pricing collapses to the fastest advertised tier (the sim
        // core), divided across both workers behind the peer.
        assert_eq!(
            be.cost_model(),
            CostModel::Remote {
                workers: 2,
                class: RemotePeerClass::SimCycles
            }
        );
        assert!(be.name().starts_with("remote@"));
        drop(be);
        server.stop();
    }

    #[test]
    fn host_only_peer_prices_as_host_class() {
        // A peer fronting only naive golden workers must advertise —
        // and be priced as — host loops, keeping local silicon
        // preferred in a mixed front pool.
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig {
                n_cores: 0,
                ..CoordinatorConfig::default().with_golden_workers(2)
            },
        )
        .unwrap();
        let be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        assert_eq!(
            be.cost_model(),
            CostModel::Remote {
                workers: 2,
                class: RemotePeerClass::HostMacs
            }
        );
        drop(be);
        server.stop();
    }

    #[test]
    fn v2_only_peer_negotiates_json_tensors_bit_identical() {
        // Satellite 3's negotiation contract: a v3 front dialling a
        // peer whose hello lacks the bin flag silently stays on JSON
        // tensors, and the answer is bit-identical to the binary path.
        let v3 = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(2),
        )
        .unwrap();
        let v2 = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(2).with_wire_v2_only(),
        )
        .unwrap();
        let mut be3 = RemoteBackend::connect(&v3.addr.to_string()).unwrap();
        let mut be2 = RemoteBackend::connect(&v2.addr.to_string()).unwrap();
        assert!(be3.peer_binary());
        assert!(!be2.peer_binary(), "v2-only hello must not offer bin");
        assert!(!be2.peer_wcache(), "v2-only hello must not offer wcache");
        assert!(!be2.peer_trace(), "v2-only hello must not offer trace");
        let spec = LayerSpec::new(3, 6, 6, 5).with_relu();
        let mut rng = Prng::new(47);
        let img = Tensor::from_vec(&[3, 6, 6], rng.bytes_below(3 * 6 * 6, 256));
        let wts = Tensor::from_vec(&[5, 3, 3, 3], rng.bytes_below(5 * 3 * 9, 256));
        let bias: Vec<i32> = (0..5).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let r3 = be3.run(&payload).unwrap();
        let r2 = be2.run(&payload).unwrap();
        let want = golden::conv3x3_i32(&img, &wts, &bias, true);
        assert_eq!(r3.output.data(), want.data(), "binary path vs golden");
        assert_eq!(r2.output.data(), want.data(), "JSON fallback vs golden");
        assert_eq!(r3.output.shape(), r2.output.shape());
        // The v2 peer saw plain inline tensors: no residency belief was
        // formed and no cache traffic was recorded.
        let known2 = be2.known_weights().unwrap();
        assert!(known2.is_empty(), "v2 path must never claim a weights hash");
        assert_eq!(known2.stats(), (0, 0, 0));
        drop(be3);
        drop(be2);
        v3.stop();
        v2.stop();
    }

    #[test]
    fn run_batch_pipelines_jobs_and_matches_golden() {
        // More jobs than the server's worker count and (deliberately)
        // fewer than the pipeline window: all of them cross the wire
        // before the first reply is read, and every id-matched answer
        // must land on the job that asked for it.
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(2),
        )
        .unwrap();
        let mut be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        let spec = LayerSpec::new(2, 5, 5, 4);
        let mut rng = Prng::new(93);
        let wts = Tensor::from_vec(&[4, 2, 3, 3], rng.bytes_below(4 * 2 * 9, 256));
        let bias: Vec<i32> = (0..4).map(|_| rng.range_i64(-50, 50) as i32).collect();
        let imgs: Vec<Tensor<u8>> = (0..6)
            .map(|_| Tensor::from_vec(&[2, 5, 5], rng.bytes_below(2 * 5 * 5, 256)))
            .collect();
        let payloads: Vec<JobPayload> = imgs
            .iter()
            .map(|img| JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .collect();
        let results = be.run_batch(&payloads);
        assert_eq!(results.len(), 6);
        for (img, res) in imgs.iter().zip(results) {
            let run = res.expect("pipelined job succeeds");
            let want = golden::conv3x3_i32(img, &wts, &bias, false);
            assert_eq!(run.output.data(), want.data());
        }
        drop(be);
        server.stop();
    }

    #[test]
    fn run_batch_against_dead_peer_fails_every_job_without_hanging() {
        let server = TcpServer::start("127.0.0.1:0", CoordinatorConfig::default()).unwrap();
        let mut be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        server.stop();
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let payloads: Vec<JobPayload> = (0..3)
            .map(|_| JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .collect();
        let results = be.run_batch(&payloads);
        assert_eq!(results.len(), 3);
        for res in results {
            let err = res.expect_err("dead peer fails the job, not hangs");
            assert!(err.to_string().contains("remote"), "{err}");
        }
    }

    #[test]
    fn run_batch_protocol_error_fails_all_inflight_without_panicking() {
        // Regression: a protocol-level bad reply (ok:true but the wrong
        // shape) mid-batch once left its job's result slot unfilled —
        // the reply id had already been removed from the in-flight map,
        // so the transport cleanup skipped it and the final unwrap
        // panicked the pool worker. Every job must come back as an
        // error instead.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            writeln!(s, "{}", hello_line()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let id1 = Json::parse(line.trim()).unwrap().get(&["id"]).unwrap().as_u64().unwrap();
            line.clear();
            r.read_line(&mut line).unwrap(); // second pipelined request
            let reply = Json::obj(vec![
                ("id", Json::uint(id1)),
                ("ok", Json::Bool(true)),
                ("compute_cycles", Json::num(8u32)),
                ("total_cycles", Json::num(8u32)),
                ("shape", Json::arr_u64([1u64, 1, 1])),
                ("output", Json::arr_i64([0i64])),
            ]);
            writeln!(s, "{}", reply.to_json()).unwrap();
        });
        let mut be = RemoteBackend::connect(&addr).unwrap();
        let spec = LayerSpec::new(1, 3, 3, 4);
        let img = Tensor::<u8>::zeros(&[1, 3, 3]);
        let wts = Tensor::<u8>::zeros(&[4, 1, 3, 3]);
        let bias = vec![0i32; 4];
        let payloads: Vec<JobPayload> = (0..2)
            .map(|_| JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .collect();
        let results = be.run_batch(&payloads);
        assert_eq!(results.len(), 2);
        for res in results {
            let err = res.expect_err("protocol error fails every in-flight job");
            assert!(err.to_string().contains("remote"), "{err}");
        }
        t.join().unwrap();
    }

    #[test]
    fn repeated_weights_ship_once_per_peer_lifetime() {
        // The PR's acceptance property at wire level: however many jobs
        // reuse one weight blob — across pipelined batches and single
        // runs alike — the bytes cross the wire exactly once per peer
        // lifetime. Ship-time marking means even the first batch
        // carries the blob in its first frame only.
        let server =
            TcpServer::start("127.0.0.1:0", CoordinatorConfig::default().with_cores(2)).unwrap();
        let mut be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        assert!(be.peer_wcache());
        let spec = LayerSpec::new(2, 5, 5, 4);
        let mut rng = Prng::new(97);
        let wts = Tensor::from_vec(&[4, 2, 3, 3], rng.bytes_below(4 * 2 * 9, 256));
        let bias: Vec<i32> = (0..4).map(|_| rng.range_i64(-50, 50) as i32).collect();
        let imgs: Vec<Tensor<u8>> = (0..6)
            .map(|_| Tensor::from_vec(&[2, 5, 5], rng.bytes_below(2 * 5 * 5, 256)))
            .collect();
        let payloads: Vec<JobPayload> = imgs
            .iter()
            .map(|img| JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img,
                weights: &wts,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            })
            .collect();
        for res in be.run_batch(&payloads) {
            res.expect("pipelined cached job succeeds");
        }
        for res in be.run_batch(&payloads) {
            res.expect("second batch rides the warm cache");
        }
        let run = be.run(&payloads[0]).unwrap();
        let want = golden::conv3x3_i32(&imgs[0], &wts, &bias, false);
        assert_eq!(run.output.data(), want.data(), "cached path stays bit-identical");
        // 13 jobs, one 72-byte blob: it crossed the wire exactly once.
        let m = server.metrics();
        assert_eq!(m.wire_weight_bytes.load(Ordering::Relaxed), 72);
        assert_eq!(m.weight_hits.load(Ordering::Relaxed), 12);
        assert_eq!(
            m.weight_misses.load(Ordering::Relaxed),
            0,
            "ship-time marking never needs a need_weights round trip here"
        );
        let (hits, misses, saved) = be.known_weights().unwrap().stats();
        assert_eq!((hits, misses, saved), (12, 1, 12 * 72));
        drop(be);
        server.stop();
    }

    #[test]
    fn redial_after_peer_flap_reships_weights_once() {
        // Satellite 1's chaos contract: kill the peer connection
        // mid-service, revive it, and the next same-model job re-ships
        // the blob exactly once (the redial dropped every residency
        // belief) with bit-identical output; the job after that is a
        // cache hit again.
        let server =
            TcpServer::start("127.0.0.1:0", CoordinatorConfig::default().with_cores(1)).unwrap();
        let mut be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        let spec = LayerSpec::new(2, 5, 5, 4);
        let mut rng = Prng::new(98);
        let wts = Tensor::from_vec(&[4, 2, 3, 3], rng.bytes_below(4 * 2 * 9, 256));
        let bias: Vec<i32> = (0..4).map(|_| rng.range_i64(-50, 50) as i32).collect();
        let img = Tensor::from_vec(&[2, 5, 5], rng.bytes_below(2 * 5 * 5, 256));
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &wts,
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        let want = golden::conv3x3_i32(&img, &wts, &bias, false);
        // Warm up: one inline ship, then a hash-only hit.
        assert_eq!(be.run(&payload).unwrap().output.data(), want.data());
        assert_eq!(be.run(&payload).unwrap().output.data(), want.data());
        assert_eq!(server.metrics().wire_weight_bytes.load(Ordering::Relaxed), 72);
        assert_eq!(be.known_weights().unwrap().len(), 1);
        // Chaos: sever the connection under the client.
        server.set_down(true);
        let err = be.run(&payload).unwrap_err();
        assert!(err.to_string().contains("remote"), "{err}");
        server.set_down(false);
        // Revival: the redial cleared the belief set, so the blob
        // re-ships inline exactly once — and stays bit-identical.
        let run = be.run(&payload).unwrap();
        assert_eq!(run.output.data(), want.data(), "bit-identical across the flap");
        assert_eq!(
            server.metrics().wire_weight_bytes.load(Ordering::Relaxed),
            144,
            "exactly one re-ship after the redial"
        );
        assert_eq!(be.known_weights().unwrap().len(), 1, "belief re-learned");
        // Back to hits: no further weight bytes cross the wire.
        assert_eq!(be.run(&payload).unwrap().output.data(), want.data());
        assert_eq!(server.metrics().wire_weight_bytes.load(Ordering::Relaxed), 144);
        let (hits, misses, saved) = be.known_weights().unwrap().stats();
        assert_eq!((hits, misses, saved), (2, 2, 144));
        drop(be);
        server.stop();
    }

    #[test]
    fn evicted_blob_recovers_via_need_weights_reship() {
        // A one-BRAM store holds exactly two 2304-byte blobs; shipping a
        // third evicts the first. The client still believes blob 0
        // resident, so its next job goes hash-only, eats the
        // need_weights round trip, re-ships inline on the same request
        // id, and still answers bit-identically.
        let server = TcpServer::start(
            "127.0.0.1:0",
            CoordinatorConfig::default().with_cores(1).with_weight_store_bram36(1),
        )
        .unwrap();
        let mut be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        let spec = LayerSpec::new(16, 6, 6, 16);
        let mut rng = Prng::new(99);
        let img = Tensor::from_vec(&[16, 6, 6], rng.bytes_below(16 * 6 * 6, 256));
        let bias = vec![0i32; 16];
        let weight_sets: Vec<Tensor<u8>> = (0..3)
            .map(|_| Tensor::from_vec(&[16, 16, 3, 3], rng.bytes_below(16 * 16 * 9, 256)))
            .collect();
        let golds: Vec<Tensor<i32>> = weight_sets
            .iter()
            .map(|w| golden::conv3x3_i32(&img, w, &bias, false))
            .collect();
        for (w, want) in weight_sets.iter().zip(&golds) {
            let payload = JobPayload {
                kind: JobKind::Standard,
                spec: &spec,
                img: &img,
                weights: w,
                bias: &bias,
                weights_resident: false,
                trace_id: 0,
            };
            assert_eq!(be.run(&payload).unwrap().output.data(), want.data());
        }
        let payload = JobPayload {
            kind: JobKind::Standard,
            spec: &spec,
            img: &img,
            weights: &weight_sets[0],
            bias: &bias,
            weights_resident: false,
            trace_id: 0,
        };
        assert_eq!(be.run(&payload).unwrap().output.data(), golds[0].data());
        let m = server.metrics();
        assert_eq!(
            m.weight_misses.load(Ordering::Relaxed),
            1,
            "exactly one need_weights round trip"
        );
        assert_eq!(m.weight_hits.load(Ordering::Relaxed), 0);
        assert_eq!(m.wire_weight_bytes.load(Ordering::Relaxed), 4 * 2304);
        let (hits, misses, _saved) = be.known_weights().unwrap().stats();
        assert_eq!((hits, misses), (0, 4));
        drop(be);
        server.stop();
    }

    #[test]
    fn dead_peer_yields_error_results_from_the_pool_not_hangs() {
        // The ISSUE's failure contract at pool level: a RemoteBackend
        // whose peer died answers dispatched jobs with error results.
        let server =
            TcpServer::start("127.0.0.1:0", CoordinatorConfig::default()).unwrap();
        let be = RemoteBackend::connect(&server.addr.to_string()).unwrap();
        server.stop();
        let backends: Vec<Box<dyn ConvBackend>> = vec![Box::new(be)];
        let pool = CorePool::with_backends(backends, IpCoreConfig::default());
        let (tx, rx) = channel();
        let job = ConvJob::synthetic(1, QUICKSTART, 1);
        pool.dispatch(Batch {
            spec: job.spec,
            weights_id: job.weights_id,
            kind: job.kind,
            accum: job.accum,
            jobs: vec![Submission {
                job,
                reply: tx,
                enqueued: std::time::Instant::now(),
            }],
        });
        let res = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("an error result, not a hang");
        assert!(res.error.is_some(), "{res:?}");
        pool.shutdown();
    }
}
