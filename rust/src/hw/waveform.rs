//! Signal tracing — reproduces the paper's Fig. 6 simulation waveform.
//!
//! The traced signals mirror the figure exactly: `weight0..3` (72-bit,
//! nine bytes of one kernel-channel), `feature0..2` (24-bit, one window
//! row each) and `psum_0..3` (8-bit). [`WaveTrace`] renders both an
//! ASCII table (what EXPERIMENTS.md quotes next to the figure) and a
//! VCD file loadable in GTKWave — the closest artefact to "a Vivado
//! waveform" a simulator can emit.

use super::compute_core::ComputeCore;
use super::pcore::Psum;
use crate::model::{LayerSpec, Tensor};
use crate::paper::N_PCORES;
use std::fmt::Write as _;

/// The Fig. 6 testbench stimulus: a 5-wide byte-ramp feature (1..25)
/// and the figure's four kernels (01..09, 91..99, 21..29, b1..b9),
/// zero bias. Windows slide by one column, rows advance by 5 — exactly
/// the `feature0..2` sequences visible in the figure.
pub fn fig6_stimulus() -> (LayerSpec, Tensor<u8>, Tensor<u8>, Vec<i32>) {
    let spec = LayerSpec::new(1, 5, 5, 4);
    let img = Tensor::from_vec(&[1, 5, 5], (1..=25u8).collect());
    let mut wdata = Vec::with_capacity(36);
    for base in [0x01u8, 0x91, 0x21, 0xb1] {
        for i in 0..9 {
            wdata.push(base + i);
        }
    }
    let weights = Tensor::from_vec(&[4, 1, 3, 3], wdata);
    (spec, img, weights, vec![0; 4])
}

/// The psum columns printed in the paper's Fig. 6 (first 9 windows),
/// one row per PCORE — the ground truth `rust/tests/fig6.rs` asserts.
pub const FIG6_PSUMS: [[u8; 9]; 4] = [
    [0x9b, 0xc8, 0xf5, 0x7c, 0xa9, 0xd6, 0x5d, 0x8a, 0xb7],
    [0x0b, 0x48, 0x85, 0x3c, 0x79, 0xb6, 0x6d, 0xaa, 0xe7],
    [0x7b, 0xc8, 0x15, 0xfc, 0x49, 0x96, 0x7d, 0xca, 0x17],
    [0xeb, 0x48, 0xa5, 0xbc, 0x19, 0x76, 0x8d, 0xea, 0x47],
];

/// One traced signal: name + bit width.
#[derive(Clone, Debug)]
pub struct Signal {
    pub name: String,
    pub bits: usize,
}

/// A recorded trace: per step, one hex value per signal.
#[derive(Clone, Debug, Default)]
pub struct WaveTrace {
    pub signals: Vec<Signal>,
    /// (cycle, values-as-hex) per step.
    pub rows: Vec<(u64, Vec<String>)>,
}

fn hex_bytes(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(s, "{b:02x}");
    }
    s
}

impl WaveTrace {
    /// The Fig. 6 signal set for one computing core.
    pub fn fig6() -> Self {
        let mut signals = Vec::new();
        for j in 0..N_PCORES {
            signals.push(Signal {
                name: format!("weight{j}[71:0]"),
                bits: 72,
            });
        }
        for r in 0..3 {
            signals.push(Signal {
                name: format!("feature{r}[23:0]"),
                bits: 24,
            });
        }
        for j in 0..N_PCORES {
            signals.push(Signal {
                name: format!("psum_{j}[7:0]"),
                bits: 8,
            });
        }
        WaveTrace {
            signals,
            rows: Vec::new(),
        }
    }

    /// Record one window step of a computing core (called from
    /// [`ComputeCore::sweep`] when tracing is on).
    pub fn record_window_step(
        &mut self,
        core: &ComputeCore,
        window: &[u8; 9],
        psums: &[Psum; N_PCORES],
        cycle: u64,
    ) {
        let mut vals = Vec::with_capacity(self.signals.len());
        for pc in &core.pcores {
            vals.push(hex_bytes(&pc.weights()));
        }
        for r in 0..3 {
            vals.push(hex_bytes(&window[r * 3..r * 3 + 3]));
        }
        for p in psums {
            let v = match p {
                Psum::Wrap8(v) => *v,
                Psum::I32(v) => (*v & 0xFF) as u8,
            };
            vals.push(format!("{v:02x}"));
        }
        self.rows.push((cycle, vals));
    }

    /// Values of one signal across all steps.
    pub fn series(&self, name: &str) -> Option<Vec<&str>> {
        let idx = self.signals.iter().position(|s| s.name.starts_with(name))?;
        Some(self.rows.iter().map(|(_, v)| v[idx].as_str()).collect())
    }

    /// ASCII rendering in the layout of the paper's figure: one line per
    /// signal, one column per step.
    pub fn render_ascii(&self) -> String {
        let mut out = String::new();
        let width = self
            .rows
            .iter()
            .flat_map(|(_, v)| v.iter().map(|s| s.len()))
            .max()
            .unwrap_or(2);
        let _ = writeln!(
            out,
            "{:<16} | {}",
            "cycle",
            self.rows
                .iter()
                .map(|(c, _)| format!("{c:>width$}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        let _ = writeln!(out, "{}", "-".repeat(18 + self.rows.len() * (width + 1)));
        for (i, sig) in self.signals.iter().enumerate() {
            let _ = writeln!(
                out,
                "{:<16} | {}",
                sig.name,
                self.rows
                    .iter()
                    .map(|(_, v)| format!("{:>width$}", v[i]))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
        out
    }

    /// Minimal VCD (value-change dump) export, loadable in GTKWave.
    pub fn to_vcd(&self, timescale_ns: u64) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "$date repro $end");
        let _ = writeln!(out, "$timescale {timescale_ns}ns $end");
        let _ = writeln!(out, "$scope module computing_core $end");
        // VCD id chars start at '!' (33).
        let ids: Vec<char> = (0..self.signals.len())
            .map(|i| char::from_u32(33 + i as u32).unwrap())
            .collect();
        for (sig, id) in self.signals.iter().zip(&ids) {
            let short = sig.name.split('[').next().unwrap();
            let _ = writeln!(out, "$var wire {} {} {} $end", sig.bits, id, short);
        }
        let _ = writeln!(out, "$upscope $end");
        let _ = writeln!(out, "$enddefinitions $end");
        let mut last: Vec<Option<&str>> = vec![None; self.signals.len()];
        for (cycle, vals) in &self.rows {
            let _ = writeln!(out, "#{cycle}");
            for (i, v) in vals.iter().enumerate() {
                if last[i] != Some(v.as_str()) {
                    let bits = u128::from_str_radix(v, 16).unwrap_or(0);
                    let _ = writeln!(out, "b{:b} {}", bits, ids[i]);
                    last[i] = Some(v.as_str());
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_signal_set() {
        let t = WaveTrace::fig6();
        assert_eq!(t.signals.len(), 4 + 3 + 4);
        assert_eq!(t.signals[0].name, "weight0[71:0]");
        assert_eq!(t.signals[0].bits, 72);
        assert_eq!(t.signals[4].name, "feature0[23:0]");
        assert_eq!(t.signals[10].name, "psum_3[7:0]");
    }

    #[test]
    fn hex_format() {
        assert_eq!(hex_bytes(&[0x01, 0x0b, 0xff]), "010bff");
    }

    #[test]
    fn vcd_has_header_and_changes() {
        let mut t = WaveTrace::fig6();
        t.rows.push((8, vec!["00".into(); 11]));
        t.rows.push((16, vec!["ff".into(); 11]));
        let vcd = t.to_vcd(10);
        assert!(vcd.contains("$enddefinitions"));
        assert!(vcd.contains("#8"));
        assert!(vcd.contains("#16"));
        assert!(vcd.matches("b11111111").count() >= 1);
    }

    #[test]
    fn ascii_contains_all_signals() {
        let mut t = WaveTrace::fig6();
        t.rows.push((8, vec!["aa".into(); 11]));
        let text = t.render_ascii();
        for sig in &t.signals {
            assert!(text.contains(&sig.name));
        }
    }
}
